//! §4.5 "What about big data?" — the three coping strategies the paper
//! prescribes, implemented and tested:
//!
//! * **Too many samples** → [`StreamingHat`]: never materialise the `N×N`
//!   hat matrix; keep `T = X̃ S` (`N×(P+1)`) and compute the per-fold blocks
//!   `H_Te = T_Te X̃_Teᵀ` on the fly (`O(N_te² P)` per fold, `O(NP)` memory).
//! * **Too many features** → [`SparseProjection`]: an Achlioptas sparse
//!   random projection `A ∈ R^{P×Q}`, `Q ≪ P`, approximately preserving the
//!   covariance structure so `XA` can replace `X`.
//! * **Both** → [`LdaEnsemble`]: weak regularised-LDA learners on random
//!   feature/sample subsets, majority-vote aggregation, trainable in
//!   parallel.

use super::FoldCache;
use crate::linalg::{matmul, Cholesky, Lu, Mat};
use crate::model::linreg::gram_ridged;
use crate::model::Reg;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Memory-light analytic CV state: `O(NP)` instead of `O(N²)`.
#[derive(Debug)]
pub struct StreamingHat {
    /// Augmented design.
    pub xa: Mat,
    /// `T = X̃ S` — the "whitened" design (§4.4's kernel view).
    pub t: Mat,
    /// Ridge used.
    pub lambda: f64,
}

impl StreamingHat {
    /// Build from raw data (same contract as [`super::hat::HatMatrix`]).
    pub fn build(x: &Mat, lambda: f64) -> Result<StreamingHat> {
        let xa = x.augment_ones();
        let g = gram_ridged(&xa, lambda);
        // T = X̃ G⁻¹ = solve(G, X̃ᵀ)ᵀ — no explicit inverse (see §Perf).
        let w = match Cholesky::factor(&g) {
            Ok(ch) => ch.solve_mat(&xa.t()),
            Err(_) => Lu::factor(&g).context("gram singular; increase λ")?.solve_mat(&xa.t()),
        };
        let t = w.t();
        Ok(StreamingHat { xa, t, lambda })
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.xa.rows()
    }

    /// On-the-fly fold block `H_Te = T_Te X̃_Teᵀ`.
    pub fn block(&self, te: &[usize]) -> Mat {
        let t_te = self.t.take_rows(te);
        let xa_te = self.xa.take_rows(te);
        matmul(&t_te, &xa_te.t())
    }

    /// Full-data fits `ŷ = H y` computed as `T (X̃ᵀ y)` — `O(NP)`, no `H`.
    pub fn fit_response(&self, y: &[f64]) -> Vec<f64> {
        let xty = crate::linalg::matvec_t(&self.xa, y);
        crate::linalg::matvec(&self.t, &xty)
    }

    /// Analytic CV decision values (Eq. 14) without materialising `H`.
    pub fn decision_values(&self, y: &[f64], folds: &[Vec<usize>]) -> Result<Vec<f64>> {
        super::validate_folds(folds, self.n())?;
        let y_hat = self.fit_response(y);
        let mut dvals = vec![f64::NAN; self.n()];
        for te in folds {
            let mut i_minus = self.block(te);
            i_minus.scale(-1.0);
            for i in 0..te.len() {
                i_minus[(i, i)] += 1.0;
            }
            let e_hat: Vec<f64> = te.iter().map(|&i| y[i] - y_hat[i]).collect();
            let e_dot = crate::linalg::solve(&i_minus, &e_hat)
                .context("(I − H_Te) singular; increase λ")?;
            for (j, &i) in te.iter().enumerate() {
                dvals[i] = y[i] - e_dot[j];
            }
        }
        Ok(dvals)
    }
}

/// Achlioptas sparse random projection: entries `±√(3/Q)` with probability
/// 1/6 each, 0 with probability 2/3 — `E[AAᵀ] = I`, so `XA` approximately
/// preserves pairwise geometry at `Q = O(log N / ε²)`.
#[derive(Debug, Clone)]
pub struct SparseProjection {
    /// Projection matrix, `P × Q` (stored sparse as (row, col, sign)).
    triplets: Vec<(u32, u32, f32)>,
    p: usize,
    q: usize,
    scale: f64,
}

impl SparseProjection {
    /// Sample a projection from `p` dims down to `q`.
    pub fn sample(p: usize, q: usize, rng: &mut Rng) -> SparseProjection {
        assert!(q >= 1);
        let mut triplets = Vec::with_capacity(p * q / 3 + 1);
        for i in 0..p {
            for j in 0..q {
                let r = rng.below(6);
                if r == 0 {
                    triplets.push((i as u32, j as u32, 1.0));
                } else if r == 1 {
                    triplets.push((i as u32, j as u32, -1.0));
                }
            }
        }
        SparseProjection { triplets, p, q, scale: (3.0 / q as f64).sqrt() }
    }

    /// Output dimensionality.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Fraction of non-zero entries (≈1/3).
    pub fn density(&self) -> f64 {
        self.triplets.len() as f64 / (self.p * self.q) as f64
    }

    /// Project a data matrix: `X A` (`N×P` → `N×Q`).
    pub fn project(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.p, "projection dimension mismatch");
        let mut out = Mat::zeros(x.rows(), self.q);
        for i in 0..x.rows() {
            let row = x.row(i);
            let orow = out.row_mut(i);
            for &(pi, qj, sign) in &self.triplets {
                orow[qj as usize] += sign as f64 * row[pi as usize];
            }
        }
        out.scale(self.scale);
        out
    }
}

/// Ensemble of weak regularised-LDA learners (§4.5): each trained on a
/// random subset of features and samples; majority vote at prediction.
pub struct LdaEnsemble {
    members: Vec<(Vec<usize>, crate::model::lda_binary::BinaryLda)>,
}

impl LdaEnsemble {
    /// Train `n_members` weak learners, each on `feat_frac` of the features
    /// and `sample_frac` of the samples, optionally in parallel on `pool`.
    pub fn train(
        x: &Mat,
        labels: &[usize],
        n_members: usize,
        feat_frac: f64,
        sample_frac: f64,
        reg: Reg,
        pool: Option<&crate::util::threadpool::ThreadPool>,
        rng: &mut Rng,
    ) -> Result<LdaEnsemble> {
        assert!(n_members >= 1);
        let p = x.cols();
        let n = x.rows();
        let n_feat = ((p as f64 * feat_frac).ceil() as usize).clamp(1, p);
        let n_samp = ((n as f64 * sample_frac).ceil() as usize).clamp(4, n);
        // Pre-draw subsets so training is deterministic regardless of pool.
        let draws: Vec<(Vec<usize>, Vec<usize>)> = (0..n_members)
            .map(|_| {
                // resample until both classes present
                loop {
                    let feats = rng.choose(p, n_feat);
                    let samps = rng.choose(n, n_samp);
                    let has0 = samps.iter().any(|&i| labels[i] == 0);
                    let has1 = samps.iter().any(|&i| labels[i] == 1);
                    if has0 && has1 {
                        return (feats, samps);
                    }
                }
            })
            .collect();
        let train_one = |(feats, samps): &(Vec<usize>, Vec<usize>)| -> Result<(Vec<usize>, crate::model::lda_binary::BinaryLda)> {
            let xs = x.take(samps, feats);
            let ls: Vec<usize> = samps.iter().map(|&i| labels[i]).collect();
            let model = crate::model::lda_binary::BinaryLda::train(&xs, &ls, reg)?;
            Ok((feats.clone(), model))
        };
        let members: Vec<_> = match pool {
            Some(pool) => {
                let slots: Vec<std::sync::Mutex<Option<_>>> =
                    (0..n_members).map(|_| std::sync::Mutex::new(None)).collect();
                let slots_ref = &slots;
                let draws_ref = &draws;
                pool.for_each(n_members, move |i| {
                    *slots_ref[i].lock().unwrap() = Some(train_one(&draws_ref[i]));
                });
                slots
                    .into_iter()
                    .map(|s| s.into_inner().unwrap().unwrap())
                    .collect::<Result<Vec<_>>>()?
            }
            None => draws.iter().map(train_one).collect::<Result<Vec<_>>>()?,
        };
        Ok(LdaEnsemble { members })
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Is the ensemble empty?
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Majority-vote prediction (ties → class 0, the "+1" class).
    pub fn predict(&self, x: &Mat) -> Vec<usize> {
        let n = x.rows();
        let mut votes1 = vec![0usize; n];
        for (feats, model) in &self.members {
            let xs = x.take_cols(feats);
            for (i, &l) in model.predict(&xs).iter().enumerate() {
                votes1[i] += l;
            }
        }
        let half = self.members.len();
        votes1.iter().map(|&v| usize::from(2 * v > half)).collect()
    }
}

/// Analytic CV on randomly projected data: the §4.5 "too many features"
/// pipeline in one call.
pub fn projected_analytic_cv(
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    q: usize,
    lambda: f64,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let proj = SparseProjection::sample(x.cols(), q, rng);
    let xq = proj.project(x);
    let cv = super::binary::AnalyticBinaryCv::fit(&xq, y, lambda)?;
    let cache = FoldCache::prepare(&cv.hat, folds, false)?;
    Ok(cv.decision_values_cached(&cache))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::prop::assert_all_close;

    #[test]
    fn streaming_hat_matches_dense_hat() {
        let mut rng = Rng::new(1);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(50, 5, &mut rng);
        let dense = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 0.7).unwrap();
        let dv_dense = dense.decision_values(&folds).unwrap();
        let stream = StreamingHat::build(&ds.x, 0.7).unwrap();
        let dv_stream = stream.decision_values(&y, &folds).unwrap();
        assert_all_close(&dv_stream, &dv_dense, 1e-9, "streaming == dense");
        // block equality
        let te = &folds[0];
        let b1 = dense.hat.block(te);
        let b2 = stream.block(te);
        assert!(b1.max_abs_diff(&b2) < 1e-10);
    }

    #[test]
    fn streaming_memory_is_np_not_n2() {
        // structural check: StreamingHat holds two N×(P+1)-ish matrices only
        let mut rng = Rng::new(2);
        let ds = generate(&SyntheticSpec::binary(60, 5), &mut rng);
        let s = StreamingHat::build(&ds.x, 0.1).unwrap();
        assert_eq!(s.t.shape(), (60, 6));
        assert_eq!(s.xa.shape(), (60, 6));
    }

    #[test]
    fn projection_preserves_geometry_approximately() {
        let mut rng = Rng::new(3);
        let p = 2000;
        let q = 300;
        let n = 20;
        let x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let proj = SparseProjection::sample(p, q, &mut rng);
        assert!((proj.density() - 1.0 / 3.0).abs() < 0.03);
        let xq = proj.project(&x);
        assert_eq!(xq.shape(), (n, q));
        // pairwise squared distances preserved within ~35%
        for i in 0..5 {
            for j in (i + 1)..5 {
                let d_orig: f64 = (0..p).map(|k| (x[(i, k)] - x[(j, k)]).powi(2)).sum();
                let d_proj: f64 = (0..q).map(|k| (xq[(i, k)] - xq[(j, k)]).powi(2)).sum();
                let ratio = d_proj / d_orig;
                assert!((0.65..1.35).contains(&ratio), "ratio={ratio}");
            }
        }
    }

    #[test]
    fn projected_cv_still_decodes() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(100, 800);
        spec.separation = 5.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = kfold(100, 5, &mut rng);
        // Unprojected baseline for context.
        let cv = super::super::binary::AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
        let acc_full = crate::cv::metrics::accuracy_signed(
            &cv.decision_values(&folds).unwrap(),
            &y,
        );
        let dv = projected_analytic_cv(&ds.x, &y, &folds, 200, 1.0, &mut rng).unwrap();
        let acc = crate::cv::metrics::accuracy_signed(&dv, &y);
        assert!(acc > 0.65, "projected CV acc={acc} (full-dim acc={acc_full})");
        assert!(acc_full > 0.75, "full-dim baseline acc={acc_full}");
    }

    #[test]
    fn ensemble_beats_weak_member_and_parallel_matches_serial() {
        let mut rng = Rng::new(5);
        let mut spec = SyntheticSpec::binary(120, 60);
        spec.separation = 1.6;
        let ds = generate(&spec, &mut rng);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let serial = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), None, &mut rng_a,
        )
        .unwrap();
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let parallel = LdaEnsemble::train(
            &ds.x, &ds.labels, 15, 0.3, 0.6, Reg::Ridge(1.0), Some(&pool), &mut rng_b,
        )
        .unwrap();
        let pred_s = serial.predict(&ds.x);
        let pred_p = parallel.predict(&ds.x);
        assert_eq!(pred_s, pred_p, "pool must not change results");
        let acc_ens = crate::cv::metrics::accuracy_labels(&pred_s, &ds.labels);
        // single weak member accuracy
        let (feats, model) = &serial.members[0];
        let acc_one = crate::cv::metrics::accuracy_labels(
            &model.predict(&ds.x.take_cols(feats)),
            &ds.labels,
        );
        assert!(
            acc_ens >= acc_one - 0.02,
            "ensemble {acc_ens} should not trail a weak member {acc_one}"
        );
        assert!(acc_ens > 0.7, "ensemble acc={acc_ens}");
    }
}
