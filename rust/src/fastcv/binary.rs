//! Analytical k-fold cross-validation for binary LDA / least squares
//! (§2.4–2.5, Algorithm 1's inner loop).
//!
//! One full-data fit gives `ŷ = Hy`; the exact cross-validated decision
//! values on each test fold follow from
//!
//! ```text
//! ė_Te = (I − H_Te)⁻¹ (y_Te − ŷ_Te)        (Eq. 14)
//! ẏ_Te = y_Te − ė_Te
//! ```
//!
//! without training any of the K fold models. The same code path serves
//! linear regression and ridge regression — `y` is then a continuous
//! response and the bias adjustment is not used.

use super::context::ComputeContext;
use super::hat::{GramBackend, HatMatrix};
use super::FoldCache;
use crate::linalg::Mat;
use anyhow::Result;

/// Analytic cross-validation engine for one dataset + response.
#[derive(Debug)]
pub struct AnalyticBinaryCv {
    /// Shared feature-side precomputation.
    pub hat: HatMatrix,
    /// Response vector (class codes ±1, or continuous).
    pub y: Vec<f64>,
    /// Full-data fits `ŷ = Hy`.
    pub y_hat: Vec<f64>,
}

impl AnalyticBinaryCv {
    /// Fit the single full-data model. `y` is the paper's response vector;
    /// for classification use ±1 codes ([`crate::model::lda_binary::signed_codes`]).
    /// Builds the hat through the primal Gram (bit-stable historical path);
    /// see [`Self::fit_with`] for the P ≫ N backends.
    pub fn fit(x: &Mat, y: &[f64], lambda: f64) -> Result<AnalyticBinaryCv> {
        Self::fit_with(x, y, lambda, GramBackend::Primal)
    }

    /// [`Self::fit`] through a chosen [`GramBackend`] (`Auto` picks by the
    /// P/N ratio — the dual backend turns the wide-data hat build from
    /// `O(NP² + P³)` into `O(N²P + N³)`). Decision values are backend-
    /// invariant to ~1e-8.
    pub fn fit_with(
        x: &Mat,
        y: &[f64],
        lambda: f64,
        backend: GramBackend,
    ) -> Result<AnalyticBinaryCv> {
        Self::fit_ctx(x, y, lambda, &ComputeContext::serial().with_backend(backend))
    }

    /// [`Self::fit`] under a [`ComputeContext`]: the context's backend
    /// picks the Gram construction, its pool (if any) fans out the hat
    /// build's GEMMs, and its [`crate::linalg::TilePolicy`] bounds the dual
    /// `K_c` build's transients. Pooled and tiled contexts are bit-identical
    /// to a serial one — both are pure wall-clock/memory knobs.
    pub fn fit_ctx(
        x: &Mat,
        y: &[f64],
        lambda: f64,
        ctx: &ComputeContext<'_>,
    ) -> Result<AnalyticBinaryCv> {
        assert_eq!(x.rows(), y.len(), "response length mismatch");
        let hat = HatMatrix::build_ctx(x, lambda, ctx)?;
        let y_hat = hat.fit_response(y);
        Ok(AnalyticBinaryCv { hat, y: y.to_vec(), y_hat })
    }

    /// Re-use an existing hat matrix with a (possibly permuted) response —
    /// the permutation-testing entry point (§2.7): `H` is label-invariant.
    pub fn with_hat(hat: HatMatrix, y: &[f64]) -> AnalyticBinaryCv {
        assert_eq!(hat.n(), y.len());
        let y_hat = hat.fit_response(y);
        AnalyticBinaryCv { hat, y: y.to_vec(), y_hat }
    }

    /// Swap in a new response without touching `H` (in-place permutation).
    pub fn set_response(&mut self, y: &[f64]) {
        assert_eq!(self.hat.n(), y.len());
        self.y.copy_from_slice(y);
        self.y_hat = self.hat.fit_response(y);
    }

    /// Cross-validated decision values `ẏ` for every sample (regression
    /// bias `b_LR`), computed fold-by-fold via Eq. 14.
    pub fn decision_values(&self, folds: &[Vec<usize>]) -> Result<Vec<f64>> {
        let cache = FoldCache::prepare(&self.hat, folds, false)?;
        Ok(self.decision_values_cached(&cache))
    }

    /// Eq. 14 against a prepared [`FoldCache`] (hot path: zero
    /// factorisations, one triangular solve per fold).
    pub fn decision_values_cached(&self, cache: &FoldCache) -> Vec<f64> {
        let mut dvals = vec![f64::NAN; self.hat.n()];
        for (k, te) in cache.folds.iter().enumerate() {
            let e_dot = self.fold_errors(te, &cache.lus[k]);
            for (j, &i) in te.iter().enumerate() {
                dvals[i] = self.y[i] - e_dot[j];
            }
        }
        dvals
    }

    /// `ė_Te = (I−H_Te)⁻¹ ê_Te` for one fold.
    fn fold_errors(&self, te: &[usize], lu: &crate::linalg::Lu) -> Vec<f64> {
        let e_hat: Vec<f64> = te.iter().map(|&i| self.y[i] - self.y_hat[i]).collect();
        lu.solve_vec(&e_hat)
    }

    /// Matrix-response variant of [`Self::set_response`] +
    /// [`Self::decision_values_cached`]: each column of `ys` (`N × B`) is an
    /// independent response (e.g. one label permutation), processed with
    /// **one** GEMM `Ŷ = H·Y` and one multi-RHS solve per fold instead of
    /// `B` matvecs and `B·K` single-RHS solves. Returns the `N × B`
    /// cross-validated decision values (`NaN` for samples not covered by
    /// any test fold). Does not touch the stored response.
    pub fn decision_values_cached_mat(&self, cache: &FoldCache, ys: &Mat) -> Mat {
        assert_eq!(ys.rows(), self.hat.n(), "response rows must equal N");
        let b = ys.cols();
        let y_hat = self.hat.fit_response_mat(ys);
        let mut dvals = Mat::from_fn(self.hat.n(), b, |_, _| f64::NAN);
        for (k, te) in cache.folds.iter().enumerate() {
            let e_hat = Mat::from_fn(te.len(), b, |j, col| {
                ys[(te[j], col)] - y_hat[(te[j], col)]
            });
            let e_dot = cache.lus[k].solve_mat(&e_hat);
            for (j, &i) in te.iter().enumerate() {
                for col in 0..b {
                    dvals[(i, col)] = ys[(i, col)] - e_dot[(j, col)];
                }
            }
        }
        dvals
    }

    /// Matrix-response variant of [`Self::decision_values_bias_adjusted`]:
    /// column `b` of `ys` is the signed-code response of the labelling
    /// `labels_cols[b]`. One GEMM + one multi-RHS solve and one cross-block
    /// GEMM per fold serve all `B` permutations; the per-column work is only
    /// the `O(N)` class-mean pass of Eq. 15.
    pub fn decision_values_bias_adjusted_mat(
        &self,
        cache: &FoldCache,
        ys: &Mat,
        labels_cols: &[Vec<usize>],
    ) -> Result<Mat> {
        assert_eq!(ys.rows(), self.hat.n(), "response rows must equal N");
        assert_eq!(ys.cols(), labels_cols.len(), "one labelling per response column");
        let cross = cache
            .cross
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("FoldCache must be prepared with with_cross=true"))?;
        let b = ys.cols();
        let y_hat = self.hat.fit_response_mat(ys);
        let mut dvals = Mat::from_fn(self.hat.n(), b, |_, _| f64::NAN);
        for (k, te) in cache.folds.iter().enumerate() {
            let tr = &cache.trains[k];
            let e_hat = Mat::from_fn(te.len(), b, |j, col| {
                ys[(te[j], col)] - y_hat[(te[j], col)]
            });
            let e_dot_te = cache.lus[k].solve_mat(&e_hat);
            // Eq. 15 for all columns at once: corr = H_{Tr,Te} Ė_Te.
            let corr = crate::linalg::matmul(&cross[k], &e_dot_te);
            for (col, labels) in labels_cols.iter().enumerate() {
                let mut sum = [0.0f64; 2];
                let mut cnt = [0usize; 2];
                for (j, &i) in tr.iter().enumerate() {
                    let e_tr = (ys[(i, col)] - y_hat[(i, col)]) + corr[(j, col)];
                    let ydot_tr = ys[(i, col)] - e_tr;
                    // lint:allow(float_accum, reason = "serial class-sum in canonical sample order; never pool-fanned")
                    sum[labels[i]] += ydot_tr;
                    cnt[labels[i]] += 1;
                }
                anyhow::ensure!(
                    cnt[0] > 0 && cnt[1] > 0,
                    "fold {k}: a class is absent from the training set"
                );
                let mu1 = sum[0] / cnt[0] as f64;
                let mu2 = sum[1] / cnt[1] as f64;
                let shift = 0.5 * (mu1 + mu2); // = b_LR − b_LDA
                for (j, &i) in te.iter().enumerate() {
                    dvals[(i, col)] = (ys[(i, col)] - e_dot_te[(j, col)]) - shift;
                }
            }
        }
        Ok(dvals)
    }

    /// Cross-validated decision values with the LDA bias adjustment (§2.5):
    /// for each fold the cross-validated *training* decision values `ẏ_Tr`
    /// (Eq. 15) give the projected class means, from which
    /// `ẏ_Te ← ẏ_Te − b_LR + b_LDA` follows without materialising `w`.
    ///
    /// `labels[i] ∈ {0,1}` with the crate's 0 ↔ +1 convention.
    pub fn decision_values_bias_adjusted(
        &self,
        cache: &FoldCache,
        labels: &[usize],
    ) -> Result<Vec<f64>> {
        let cross = cache
            .cross
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("FoldCache must be prepared with with_cross=true"))?;
        let mut dvals = vec![f64::NAN; self.hat.n()];
        for (k, te) in cache.folds.iter().enumerate() {
            let tr = &cache.trains[k];
            let e_dot_te = self.fold_errors(te, &cache.lus[k]);
            // Eq. 15: ė_Tr = ê_Tr + H_{Tr,Te} ė_Te ; ẏ_Tr = y_Tr − ė_Tr
            let h_cross = &cross[k];
            // GEMM order: bit-identical to one column of the batched
            // `matmul(cross, Ė)` in `decision_values_bias_adjusted_mat`.
            let corr = crate::linalg::matvec_gemm_order(h_cross, &e_dot_te);
            // Projected class means on the training set (include b_LR).
            let mut sum = [0.0f64; 2];
            let mut cnt = [0usize; 2];
            for (j, &i) in tr.iter().enumerate() {
                let e_tr = (self.y[i] - self.y_hat[i]) + corr[j];
                let ydot_tr = self.y[i] - e_tr;
                // lint:allow(float_accum, reason = "serial class-sum in canonical sample order; never pool-fanned")
                sum[labels[i]] += ydot_tr;
                cnt[labels[i]] += 1;
            }
            anyhow::ensure!(
                cnt[0] > 0 && cnt[1] > 0,
                "fold {k}: a class is absent from the training set"
            );
            let mu1 = sum[0] / cnt[0] as f64;
            let mu2 = sum[1] / cnt[1] as f64;
            let shift = 0.5 * (mu1 + mu2); // = b_LR − b_LDA
            for (j, &i) in te.iter().enumerate() {
                dvals[i] = (self.y[i] - e_dot_te[j]) - shift;
            }
        }
        Ok(dvals)
    }
}

impl AnalyticBinaryCv {
    /// Leave-one-out special case of Eq. 14: with singleton test sets,
    /// `(I − H_Te)` is the scalar `1 − h_ii`, so
    /// `ẏᵢ = yᵢ − (yᵢ − ŷᵢ)/(1 − hᵢᵢ)` — the classic LOOCV identity the
    /// paper cites (Cook & Weisberg 1982; James et al. 2013). `O(N)` after
    /// the hat build, no solves at all.
    pub fn decision_values_loo(&self) -> Result<Vec<f64>> {
        let n = self.hat.n();
        let mut dvals = Vec::with_capacity(n);
        for i in 0..n {
            let denom = 1.0 - self.hat.h[(i, i)];
            anyhow::ensure!(
                denom.abs() > 1e-12,
                "sample {i}: leverage h_ii = 1 — LOO model undefined (λ=0, P ≥ N−1?)"
            );
            dvals.push(self.y[i] - (self.y[i] - self.y_hat[i]) / denom);
        }
        Ok(dvals)
    }
}

/// Reference implementation: the *standard approach* — retrain the
/// least-squares model on every training fold and predict the test fold.
/// This is the baseline every analytic result is checked against and timed
/// against (Fig. 3).
pub fn standard_cv_decision_values(
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    lambda: f64,
) -> Result<Vec<f64>> {
    super::validate_folds(folds, x.rows())?;
    let mut dvals = vec![f64::NAN; x.rows()];
    for te in folds {
        let tr = super::complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
        let model = crate::model::linreg::LinReg::fit(&x_tr, &y_tr, lambda)?;
        let x_te = x.take_rows(te);
        let pred = model.predict(&x_te);
        for (j, &i) in te.iter().enumerate() {
            dvals[i] = pred[j];
        }
    }
    Ok(dvals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::kfold;
    use crate::model::lda_binary::signed_codes;
    use crate::model::regression_lda::RegressionLda;
    use crate::util::prop::{assert_all_close, Cases};
    use crate::util::rng::Rng;

    fn labelled_problem(rng: &mut Rng, n1: usize, n2: usize, p: usize) -> (Mat, Vec<usize>) {
        let n = n1 + n2;
        let mut x = Mat::from_fn(n, p, |_, _| rng.gauss());
        let dir = rng.unit_vector(p);
        for i in 0..n1 {
            for j in 0..p {
                x[(i, j)] += 1.2 * dir[j];
            }
        }
        let labels: Vec<usize> = (0..n).map(|i| usize::from(i >= n1)).collect();
        (x, labels)
    }

    #[test]
    fn exactness_vs_standard_approach() {
        // THE core claim (Eq. 14): analytic CV decision values are *exact*,
        // matching retrain-per-fold to numerical precision, across shapes,
        // folds, ridge values, and class balances.
        Cases::new(40).run("analytic == standard (binary)", |rng| {
            let (n, p) = crate::util::prop::dims(rng);
            let n1 = n / 2 + rng.below(n / 4 + 1);
            let n2 = n - n1;
            if n2 < 3 {
                return;
            }
            let (x, labels) = labelled_problem(rng, n1, n2, p);
            let lambda = crate::util::prop::ridge(rng, p + 1 < (n - n.div_ceil(3)));
            let y = signed_codes(&labels);
            let k = crate::util::prop::folds(rng, n);
            let folds = kfold(n, k, rng);
            let std_dv = match standard_cv_decision_values(&x, &y, &folds, lambda) {
                Ok(d) => d,
                Err(_) => return, // singular unridged fold — valid skip
            };
            let cv = match AnalyticBinaryCv::fit(&x, &y, lambda) {
                Ok(cv) => cv,
                Err(_) => return,
            };
            let ana_dv = match cv.decision_values(&folds) {
                Ok(d) => d,
                Err(_) => return,
            };
            assert_all_close(&ana_dv, &std_dv, 1e-6, "decision values");
        });
    }

    #[test]
    fn bias_adjusted_matches_per_fold_lda_bias() {
        Cases::new(25).run("bias adjust == per-fold b_LDA", |rng| {
            let n1 = 8 + rng.below(15);
            let n2 = 5 + rng.below(10); // unbalanced on purpose
            let p = 1 + rng.below(6);
            let (x, labels) = labelled_problem(rng, n1, n2, p);
            let n = n1 + n2;
            let lambda = 10f64.powf(rng.uniform_in(-3.0, 1.0));
            let y = signed_codes(&labels);
            let folds = kfold(n, 4, rng);
            let cv = AnalyticBinaryCv::fit(&x, &y, lambda).unwrap();
            let cache = FoldCache::prepare(&cv.hat, &folds, true).unwrap();
            let adjusted = match cv.decision_values_bias_adjusted(&cache, &labels) {
                Ok(d) => d,
                Err(_) => return, // a fold lost a class — valid skip
            };
            // Reference: per-fold regression-LDA with b_LDA.
            for te in &folds {
                let tr = super::super::complement(te, n);
                let x_tr = x.take_rows(&tr);
                let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
                if l_tr.iter().all(|&l| l == 0) || l_tr.iter().all(|&l| l == 1) {
                    return;
                }
                let model = RegressionLda::train(&x_tr, &l_tr, lambda).unwrap();
                let pred = model.decision_values_lda(&x.take_rows(te));
                for (j, &i) in te.iter().enumerate() {
                    crate::util::prop::assert_close(adjusted[i], pred[j], 1e-6, "adjusted dval");
                }
            }
        });
    }

    #[test]
    fn loo_matches_standard() {
        let mut rng = Rng::new(11);
        let (x, labels) = labelled_problem(&mut rng, 10, 8, 4);
        let y = signed_codes(&labels);
        let folds: Vec<Vec<usize>> = (0..18).map(|i| vec![i]).collect();
        let std_dv = standard_cv_decision_values(&x, &y, &folds, 0.01).unwrap();
        let cv = AnalyticBinaryCv::fit(&x, &y, 0.01).unwrap();
        let ana = cv.decision_values(&folds).unwrap();
        assert_all_close(&ana, &std_dv, 1e-7, "LOO");
    }

    #[test]
    fn loo_shortcut_matches_general_path() {
        // ẏᵢ = yᵢ − êᵢ/(1−hᵢᵢ) must equal Eq. 14 with singleton folds, and
        // hence the retrained models.
        Cases::new(20).run("loo-shortcut", |rng| {
            let n1 = 6 + rng.below(12);
            let n2 = 6 + rng.below(12);
            let p = 1 + rng.below(6);
            let (x, labels) = labelled_problem(rng, n1, n2, p);
            let n = n1 + n2;
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let y = signed_codes(&labels);
            let cv = AnalyticBinaryCv::fit(&x, &y, lambda).unwrap();
            let fast = cv.decision_values_loo().unwrap();
            let folds: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
            let general = cv.decision_values(&folds).unwrap();
            assert_all_close(&fast, &general, 1e-9, "LOO shortcut vs Eq.14");
        });
    }

    #[test]
    fn continuous_response_regression_cv() {
        // Same machinery, continuous y (the "all least-squares models" claim).
        let mut rng = Rng::new(12);
        let n = 30;
        let x = Mat::from_fn(n, 5, |_, _| rng.gauss());
        let y: Vec<f64> = (0..n).map(|i| 2.0 * x[(i, 0)] - x[(i, 3)] + 0.1 * rng.gauss()).collect();
        let folds = kfold(n, 6, &mut rng);
        let std_dv = standard_cv_decision_values(&x, &y, &folds, 0.5).unwrap();
        let cv = AnalyticBinaryCv::fit(&x, &y, 0.5).unwrap();
        let ana = cv.decision_values(&folds).unwrap();
        assert_all_close(&ana, &std_dv, 1e-8, "regression CV");
    }

    #[test]
    fn set_response_reuses_hat() {
        let mut rng = Rng::new(13);
        let (x, labels) = labelled_problem(&mut rng, 10, 10, 3);
        let y = signed_codes(&labels);
        let folds = kfold(20, 5, &mut rng);
        let mut cv = AnalyticBinaryCv::fit(&x, &y, 0.1).unwrap();
        let dv1 = cv.decision_values(&folds).unwrap();
        // permute and back
        let mut y_perm = y.clone();
        y_perm.reverse();
        cv.set_response(&y_perm);
        let dv_perm = cv.decision_values(&folds).unwrap();
        let ref_perm = standard_cv_decision_values(&x, &y_perm, &folds, 0.1).unwrap();
        assert_all_close(&dv_perm, &ref_perm, 1e-7, "permuted response");
        cv.set_response(&y);
        let dv2 = cv.decision_values(&folds).unwrap();
        assert_all_close(&dv1, &dv2, 1e-12, "restored response");
    }

    #[test]
    fn mat_variant_matches_serial_per_column() {
        // Columnwise equality of the batched response path with the serial
        // set_response path. Both the full-data fits (matvec_gemm_order vs
        // one GEMM column) and the fold solves (solve_vec vs solve_mat
        // column) share their accumulation order, so this is bitwise.
        Cases::new(15).run("mat-response == serial", |rng| {
            let n1 = 6 + rng.below(10);
            let n2 = 6 + rng.below(10);
            let p = 1 + rng.below(8);
            let (x, labels) = labelled_problem(rng, n1, n2, p);
            let n = n1 + n2;
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let y = signed_codes(&labels);
            let folds = kfold(n, 2 + rng.below(4), rng);
            let mut cv = AnalyticBinaryCv::fit(&x, &y, lambda).unwrap();
            let cache = FoldCache::prepare(&cv.hat, &folds, true).unwrap();
            // three permuted responses as columns
            let b = 3;
            let mut cols: Vec<Vec<f64>> = Vec::new();
            let mut labels_cols: Vec<Vec<usize>> = Vec::new();
            for _ in 0..b {
                let perm = rng.permutation(n);
                labels_cols.push(perm.iter().map(|&i| labels[i]).collect());
                cols.push(signed_codes(labels_cols.last().unwrap()));
            }
            let ys = Mat::from_fn(n, b, |i, c| cols[c][i]);
            let batched = cv.decision_values_cached_mat(&cache, &ys);
            let adjusted = cv.decision_values_bias_adjusted_mat(&cache, &ys, &labels_cols);
            for c in 0..b {
                cv.set_response(&cols[c]);
                let serial = cv.decision_values_cached(&cache);
                let col: Vec<f64> = (0..n).map(|i| batched[(i, c)]).collect();
                assert_all_close(&col, &serial, 1e-14, "cached mat column");
                if let Ok(adj) = &adjusted {
                    if let Ok(serial_adj) =
                        cv.decision_values_bias_adjusted(&cache, &labels_cols[c])
                    {
                        let col: Vec<f64> = (0..n).map(|i| adj[(i, c)]).collect();
                        assert_all_close(&col, &serial_adj, 1e-14, "bias-adjusted mat column");
                    }
                }
            }
        });
    }

    #[test]
    fn backend_equivalence_binary_decision_values() {
        // Acceptance: primal/dual/spectral decision values agree to 1e-8 —
        // raw (b_LR) and bias-adjusted (b_LDA) — on wide (P ≫ N) and tall
        // (N ≫ P) shapes.
        use crate::fastcv::hat::{GramBackend, SpectralGram};
        Cases::new(12).run("backend-invariant dvals (binary)", |rng| {
            let wide = rng.below(2) == 0;
            let n1 = 8 + rng.below(8);
            let n2 = 8 + rng.below(8);
            let n = n1 + n2;
            let p = if wide { n + 20 + rng.below(60) } else { 1 + rng.below(n / 2) };
            let (x, labels) = labelled_problem(rng, n1, n2, p);
            // λ bounded away from the interpolation regime: as λ → 0 with
            // P ≫ N, (I − H_Te) → 0 and its solve amplifies the ~1e-12
            // backend roundoff past any fixed tolerance.
            let lambda = 10f64.powf(rng.uniform_in(-0.5, 1.5));
            let y = signed_codes(&labels);
            let folds = kfold(n, 2 + rng.below(4), rng);
            let primal = AnalyticBinaryCv::fit_with(&x, &y, lambda, GramBackend::Primal).unwrap();
            let dual = AnalyticBinaryCv::fit_with(&x, &y, lambda, GramBackend::Dual).unwrap();
            let spectral =
                AnalyticBinaryCv::with_hat(SpectralGram::build(&x, None).hat(lambda).unwrap(), &y);
            let cache_p = FoldCache::prepare(&primal.hat, &folds, true).unwrap();
            let cache_d = FoldCache::prepare(&dual.hat, &folds, true).unwrap();
            let cache_s = FoldCache::prepare(&spectral.hat, &folds, true).unwrap();
            let dv_p = primal.decision_values_cached(&cache_p);
            let dv_d = dual.decision_values_cached(&cache_d);
            let dv_s = spectral.decision_values_cached(&cache_s);
            assert_all_close(&dv_d, &dv_p, 1e-8, "dual vs primal dvals");
            assert_all_close(&dv_s, &dv_p, 1e-8, "spectral vs primal dvals");
            // bias-adjusted path (skip when a fold loses a class)
            if let Ok(adj_p) = primal.decision_values_bias_adjusted(&cache_p, &labels) {
                let adj_d = dual.decision_values_bias_adjusted(&cache_d, &labels).unwrap();
                let adj_s = spectral.decision_values_bias_adjusted(&cache_s, &labels).unwrap();
                assert_all_close(&adj_d, &adj_p, 1e-8, "dual vs primal bias-adjusted");
                assert_all_close(&adj_s, &adj_p, 1e-8, "spectral vs primal bias-adjusted");
            }
        });
    }

    #[test]
    fn backend_pool_fit_ctx_bitwise_matches_fit_with() {
        // fit_ctx under a pooled context must reproduce fit_with (serial)
        // to the last bit, for every backend, on a wide shape.
        use crate::fastcv::hat::GramBackend;
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(15);
        let (x, labels) = labelled_problem(&mut rng, 10, 10, 70);
        let y = signed_codes(&labels);
        let folds = kfold(20, 4, &mut rng);
        for backend in [GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral] {
            let serial = AnalyticBinaryCv::fit_with(&x, &y, 1.0, backend).unwrap();
            let ctx = ComputeContext::with_threads(4).with_backend(backend);
            let pooled = AnalyticBinaryCv::fit_ctx(&x, &y, 1.0, &ctx).unwrap();
            assert_eq!(serial.hat.h.as_slice(), pooled.hat.h.as_slice(), "{backend:?} hat");
            let dv_s = serial.decision_values(&folds).unwrap();
            let dv_p = pooled.decision_values(&folds).unwrap();
            for (a, b) in dv_s.iter().zip(&dv_p) {
                assert_eq!(a.to_bits(), b.to_bits(), "{backend:?} dvals");
            }
        }
    }

    #[test]
    fn every_sample_gets_a_decision_value() {
        let mut rng = Rng::new(14);
        let (x, labels) = labelled_problem(&mut rng, 9, 9, 3);
        let y = signed_codes(&labels);
        let folds = kfold(18, 5, &mut rng);
        let cv = AnalyticBinaryCv::fit(&x, &y, 0.1).unwrap();
        let dv = cv.decision_values(&folds).unwrap();
        assert!(dv.iter().all(|v| v.is_finite()));
    }
}
