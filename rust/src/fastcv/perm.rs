//! Permutation testing with the analytical approach (§2.7, Alg. 1 & 2).
//!
//! The hat matrix depends on features only, so across permutations only
//! `ŷ = H y^σ` and the fold solves are recomputed; `H` and the per-fold
//! `(I − H_Te)` LU factors are built **once**. The standard-approach
//! engines retrain every fold model for every permutation — that contrast
//! is exactly the paper's Fig. 3b/3d/Fig. 4 measurement.
//!
//! ## Permutation indexing (determinism contract)
//!
//! Every engine draws **one** `u64` anchor seed from the caller's RNG and
//! derives permutation `t` as an independent Fisher–Yates shuffle of the
//! *original* labelling using the counter-seeded stream
//! [`Rng::stream`]`(anchor, t)` (see [`permuted_labels`]). Permutations are
//! therefore addressable by index: any engine — serial ([`self`]), batched
//! or batched+threaded ([`super::perm_batch`]) — that agrees on the anchor
//! produces the *identical* sequence of permuted labellings, so their null
//! distributions match bit-for-bit regardless of batch size, thread count,
//! or evaluation order. Two engines handed RNGs in the same state (e.g.
//! `Rng::new(s)` twice) also see identical permutations, which is what the
//! analytic-vs-standard agreement tests rely on.

use super::binary::AnalyticBinaryCv;
use super::context::ComputeContext;
use super::hat::GramBackend;
use super::multiclass::AnalyticMulticlassCv;
use super::FoldCache;
use crate::cv::metrics::{accuracy_labels, accuracy_signed};
use crate::linalg::Mat;
use crate::model::lda_binary::signed_codes;
use crate::model::Reg;
use crate::util::rng::Rng;
use anyhow::Result;

/// Result of a permutation test.
#[derive(Clone, Debug)]
pub struct PermutationResult {
    /// Performance with the true labelling.
    pub observed: f64,
    /// Performance under each permutation (the null distribution).
    pub null: Vec<f64>,
    /// Monte-Carlo p-value with the +1 correction
    /// (Phipson & Smyth: p = (1 + #{null ≥ observed}) / (1 + n_perm)).
    pub p_value: f64,
}

pub(crate) fn p_value(observed: f64, null: &[f64]) -> f64 {
    let ge = null.iter().filter(|&&v| v >= observed).count();
    (1 + ge) as f64 / (1 + null.len()) as f64
}

/// Labels of permutation `idx` in the family anchored at `anchor`: an
/// independent Fisher–Yates shuffle of the original labelling drawn from
/// the counter-seeded stream [`Rng::stream`]`(anchor, idx)`.
///
/// A pure function of `(labels, anchor, idx)` — the determinism contract
/// shared by the serial and batched engines (see the module docs).
pub fn permuted_labels(labels: &[usize], anchor: u64, idx: u64) -> Vec<usize> {
    let mut rng = Rng::stream(anchor, idx);
    let mut perm = labels.to_vec();
    rng.shuffle(&mut perm);
    perm
}

/// Analytic binary permutation test (Algorithm 1). Accuracy metric.
///
/// `bias_adjust = false` uses the raw regression decision values (`b_LR`,
/// the paper's Alg. 1 as printed); `bias_adjust = true` applies the §2.5
/// correction per fold so results are *identical* to retraining classic LDA
/// with `b_LDA` even for unbalanced training folds.
///
/// The default backend is [`GramBackend::Auto`] (ROADMAP's `Primal` → `Auto`
/// flip): the one-off hat build resolves per shape — `Dual` on wide
/// (`P > N`, λ > 0) data, `Primal` otherwise. Null distributions are
/// backend-invariant in practice: the hat is shared per run and accuracies
/// are 1/N-quantised, so the ~1e-9 cross-backend hat roundoff can only
/// move a null entry when a decision value lands within that roundoff of
/// the classification threshold. The invariance is pinned on fixed-seed
/// grids by the golden contract
/// `backend_golden_null_distributions_recorded_for_default_flip`; a
/// caller with a knife-edge dataset who needs the historical build
/// bit-for-bit should force it via
/// [`analytic_binary_permutation_backend`] with `Primal`.
pub fn analytic_binary_permutation(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
) -> Result<PermutationResult> {
    analytic_binary_permutation_backend(
        x,
        labels,
        folds,
        lambda,
        n_perm,
        bias_adjust,
        rng,
        GramBackend::Auto,
    )
}

/// [`analytic_binary_permutation`] with an explicit [`GramBackend`] for the
/// one-off hat build. The permutation stream itself is hat-construction
/// agnostic — `H` is built once, so the null distribution is backend-
/// invariant up to the ~1e-8 hat roundoff (property-tested).
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_backend(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
    backend: GramBackend,
) -> Result<PermutationResult> {
    analytic_binary_permutation_ctx(
        x,
        labels,
        folds,
        lambda,
        n_perm,
        bias_adjust,
        rng,
        &ComputeContext::serial().with_backend(backend),
    )
}

/// [`analytic_binary_permutation`] under a [`ComputeContext`]: the
/// context's pool fans out the one-off hat build (the only feature-side
/// work — everything per permutation is `O(N²)`), bit-identically to a
/// serial build, so the null distribution is pool-invariant.
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_ctx(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
    ctx: &ComputeContext<'_>,
) -> Result<PermutationResult> {
    let y = signed_codes(labels);
    let mut cv = AnalyticBinaryCv::fit_ctx(x, &y, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, bias_adjust, ctx.pool())?;
    let dvals = |cv: &AnalyticBinaryCv, labels: &[usize]| -> Result<Vec<f64>> {
        if bias_adjust {
            cv.decision_values_bias_adjusted(&cache, labels)
        } else {
            Ok(cv.decision_values_cached(&cache))
        }
    };
    let observed = accuracy_signed(&dvals(&cv, labels)?, &y);
    let anchor = rng.next_u64();
    let mut null = Vec::with_capacity(n_perm);
    for t in 0..n_perm {
        let labels_perm = permuted_labels(labels, anchor, t as u64);
        let y_perm = signed_codes(&labels_perm);
        cv.set_response(&y_perm);
        null.push(accuracy_signed(&dvals(&cv, &labels_perm)?, &y_perm));
    }
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

/// Standard-approach binary permutation test: retrains classic LDA on every
/// fold of every permutation (the baseline timing of Fig. 3b / Fig. 4).
pub fn standard_binary_permutation(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    reg: Reg,
    n_perm: usize,
    rng: &mut Rng,
) -> Result<PermutationResult> {
    let observed = crate::cv::runner::standard_binary_cv_accuracy(x, labels, folds, reg)?;
    let anchor = rng.next_u64();
    let mut null = Vec::with_capacity(n_perm);
    for t in 0..n_perm {
        let labels_perm = permuted_labels(labels, anchor, t as u64);
        null.push(crate::cv::runner::standard_binary_cv_accuracy(x, &labels_perm, folds, reg)?);
    }
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

/// Analytic multi-class permutation test (Algorithm 2). Default backend
/// [`GramBackend::Auto`], like [`analytic_binary_permutation`] (same
/// backend-invariance argument, same golden-contract pin).
pub fn analytic_multiclass_permutation(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
) -> Result<PermutationResult> {
    analytic_multiclass_permutation_backend(
        x,
        labels,
        c,
        folds,
        lambda,
        n_perm,
        rng,
        GramBackend::Auto,
    )
}

/// [`analytic_multiclass_permutation`] with an explicit [`GramBackend`].
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_backend(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
    backend: GramBackend,
) -> Result<PermutationResult> {
    analytic_multiclass_permutation_ctx(
        x,
        labels,
        c,
        folds,
        lambda,
        n_perm,
        rng,
        &ComputeContext::serial().with_backend(backend),
    )
}

/// [`analytic_multiclass_permutation`] under a [`ComputeContext`] (pool
/// fan-out of the one-off hat build; bit-identical to serial).
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_ctx(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
    ctx: &ComputeContext<'_>,
) -> Result<PermutationResult> {
    let mut cv = AnalyticMulticlassCv::fit_ctx(x, labels, c, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, true, ctx.pool())?;
    let observed = accuracy_labels(&cv.predict_cached(&cache)?, labels);
    let anchor = rng.next_u64();
    let mut null = Vec::with_capacity(n_perm);
    for t in 0..n_perm {
        let labels_perm = permuted_labels(labels, anchor, t as u64);
        cv.set_labels(&labels_perm);
        null.push(accuracy_labels(&cv.predict_cached(&cache)?, &labels_perm));
    }
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

/// Standard-approach multi-class permutation test.
pub fn standard_multiclass_permutation(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    reg: Reg,
    n_perm: usize,
    rng: &mut Rng,
) -> Result<PermutationResult> {
    let observed = crate::cv::runner::standard_multiclass_cv_accuracy(x, labels, c, folds, reg)?;
    let anchor = rng.next_u64();
    let mut null = Vec::with_capacity(n_perm);
    for t in 0..n_perm {
        let labels_perm = permuted_labels(labels, anchor, t as u64);
        null.push(crate::cv::runner::standard_multiclass_cv_accuracy(
            x,
            &labels_perm,
            c,
            folds,
            reg,
        )?);
    }
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::model::lda_multiclass::tests::blobs;

    #[test]
    fn separable_data_rejects_null_binary() {
        let mut rng = Rng::new(1);
        let (x, labels) = blobs(&mut rng, 25, 2, 6, 3.5);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let res = analytic_binary_permutation(&x, &labels, &folds, 0.1, 99, false, &mut rng).unwrap();
        assert!(res.observed > 0.85, "observed={}", res.observed);
        assert!(res.p_value <= 0.05, "p={}", res.p_value);
        assert_eq!(res.null.len(), 99);
    }

    #[test]
    fn null_data_keeps_null_binary() {
        let mut rng = Rng::new(2);
        let (x, mut labels) = blobs(&mut rng, 25, 2, 6, 3.5);
        rng.shuffle(&mut labels); // destroy the signal
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let res = analytic_binary_permutation(&x, &labels, &folds, 0.1, 99, false, &mut rng).unwrap();
        assert!(res.p_value > 0.05, "p={} (expected non-significant)", res.p_value);
    }

    #[test]
    fn analytic_and_standard_null_distributions_agree() {
        // With identical permutation streams, the two engines must compute
        // identical null accuracies — exactness under permutation.
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 15, 2, 4, 2.0);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        let a = analytic_binary_permutation(&x, &labels, &folds, 0.5, 20, true, &mut rng_a).unwrap();
        // standard engine permutes labels; analytic permutes signed codes.
        // Identical RNG + identical shuffle source ⇒ same permutations.
        let b = standard_binary_permutation(&x, &labels, &folds, Reg::Ridge(0.5), 20, &mut rng_b)
            .unwrap();
        assert!((a.observed - b.observed).abs() < 1e-12);
        for (x1, x2) in a.null.iter().zip(&b.null) {
            assert!((x1 - x2).abs() < 1e-12, "null mismatch: {x1} vs {x2}");
        }
    }

    #[test]
    fn multiclass_engines_agree_under_permutation() {
        let mut rng = Rng::new(4);
        let (x, labels) = blobs(&mut rng, 12, 3, 5, 2.5);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let mut rng_a = Rng::new(55);
        let mut rng_b = Rng::new(55);
        let a = analytic_multiclass_permutation(&x, &labels, 3, &folds, 0.3, 10, &mut rng_a).unwrap();
        let b =
            standard_multiclass_permutation(&x, &labels, 3, &folds, Reg::Ridge(0.3), 10, &mut rng_b)
                .unwrap();
        assert!((a.observed - b.observed).abs() < 1e-12);
        for (x1, x2) in a.null.iter().zip(&b.null) {
            assert!((x1 - x2).abs() < 1e-12, "null mismatch: {x1} vs {x2}");
        }
    }

    #[test]
    fn backend_equivalence_permutation_null_distributions() {
        // Acceptance: the perm front-end is backend-invariant — identical
        // observed accuracy, null distribution, and p-value through every
        // Gram backend (accuracies are 1/N-quantised, so the ~1e-9 hat
        // roundoff cannot move them off a knife edge here).
        let mut rng = Rng::new(9);
        let (x, labels) = blobs(&mut rng, 12, 2, 60, 2.5); // wide: P ≫ N
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let base = analytic_binary_permutation_backend(
            &x, &labels, &folds, 1.0, 15, true, &mut Rng::new(42), GramBackend::Primal,
        )
        .unwrap();
        for backend in [GramBackend::Dual, GramBackend::Spectral, GramBackend::Auto] {
            let r = analytic_binary_permutation_backend(
                &x, &labels, &folds, 1.0, 15, true, &mut Rng::new(42), backend,
            )
            .unwrap();
            assert_eq!(r.observed, base.observed, "{backend:?} observed");
            assert_eq!(r.null, base.null, "{backend:?} null distribution");
            assert_eq!(r.p_value, base.p_value, "{backend:?} p-value");
        }
        // multi-class front-end too
        let (x, labels) = blobs(&mut rng, 10, 3, 50, 2.5);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let base = analytic_multiclass_permutation_backend(
            &x, &labels, 3, &folds, 1.0, 8, &mut Rng::new(43), GramBackend::Primal,
        )
        .unwrap();
        for backend in [GramBackend::Dual, GramBackend::Spectral] {
            let r = analytic_multiclass_permutation_backend(
                &x, &labels, 3, &folds, 1.0, 8, &mut Rng::new(43), backend,
            )
            .unwrap();
            assert_eq!(r.observed, base.observed, "{backend:?} multiclass observed");
            assert_eq!(r.null, base.null, "{backend:?} multiclass null");
        }
    }

    #[test]
    fn backend_pool_permutation_null_bitwise_matches_serial() {
        // A pooled context must not move a single bit of either engine's
        // observed accuracy, null distribution, or p-value.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(23);
        let (x, labels) = blobs(&mut rng, 12, 2, 70, 2.5); // wide
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let serial = analytic_binary_permutation_backend(
            &x, &labels, &folds, 1.0, 12, true, &mut Rng::new(6), GramBackend::Dual,
        )
        .unwrap();
        let ctx = ComputeContext::with_threads(4).with_backend(GramBackend::Dual);
        let pooled = analytic_binary_permutation_ctx(
            &x, &labels, &folds, 1.0, 12, true, &mut Rng::new(6), &ctx,
        )
        .unwrap();
        assert_eq!(pooled.observed, serial.observed);
        assert_eq!(pooled.null, serial.null);
        assert_eq!(pooled.p_value, serial.p_value);
        // multi-class front-end too
        let (x, labels) = blobs(&mut rng, 10, 3, 50, 2.5);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let serial = analytic_multiclass_permutation_backend(
            &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(8), GramBackend::Spectral,
        )
        .unwrap();
        let ctx = ComputeContext::with_threads(4).with_backend(GramBackend::Spectral);
        let pooled = analytic_multiclass_permutation_ctx(
            &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(8), &ctx,
        )
        .unwrap();
        assert_eq!(pooled.observed, serial.observed);
        assert_eq!(pooled.null, serial.null);
    }

    #[test]
    fn p_value_plus_one_correction() {
        assert_eq!(p_value(1.0, &[0.5, 0.5, 0.5]), 0.25);
        assert_eq!(p_value(0.4, &[0.5, 0.5, 0.5]), 1.0);
    }
}
