//! Ridge-parameter selection by analytic cross-validation.
//!
//! The classic pain of regularised LDA is that tuning λ multiplies the CV
//! cost by the grid size. The analytic approach shares everything λ-free
//! across the grid through a [`GramCache`]: the primal path computes the
//! gram `X̃ᵀX̃` **once** (each candidate pays only the factorisation and the
//! hat GEMM), and on wide (P ≫ N) shapes the spectral path goes further —
//! one eigendecomposition of the centered `N×N` Gram after which every
//! candidate is a single `O(N³)` GEMM, no `O(P³)` anywhere. No per-fold
//! refits in any case. This module implements that loop for binary/
//! regression responses ([`search_lambda`]) **and** for multi-class LDA
//! ([`search_lambda_multiclass`], where step 1 of optimal scoring shares
//! the cache and step 2 is `O(C³)` per candidate), plus the §2.6.2
//! shrinkage-grid convenience through the Eq. 18 conversion and nested CV
//! ([`nested_cv`]) for honest reporting of tuned performance.
//!
//! The `_ctx` entry points take a
//! [`ComputeContext`](super::context::ComputeContext): its pool fans out
//! the Gram/hat GEMMs (bit-identically to serial), and its nested-sharing
//! knob lets [`nested_cv_ctx`] reuse one full-data Gram across all outer
//! folds through the [`SharedNestedGram`] downdate.
//!
//! Selection is NaN-safe: an undefined metric (NaN — e.g. AUC on a
//! single-class labelling) orders below every real score *and* below the
//! −∞ of an infeasible candidate (one whose hat build **or** fold factor
//! `(I − H_Te)` is singular at that λ), and a grid on which **every**
//! candidate is infeasible returns an error instead of silently
//! "selecting" a λ.

use super::binary::AnalyticBinaryCv;
use super::context::ComputeContext;
use super::hat::{GramBackend, GramCache, HatMatrix, SharedNestedGram};
use super::multiclass::AnalyticMulticlassCv;
use super::FoldCache;
use crate::cv::metrics::{accuracy_labels, accuracy_signed, auc};
use crate::linalg::{Mat, TilePolicy};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;

/// Model-selection metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectBy {
    /// Classification accuracy of the signed decision values.
    Accuracy,
    /// Area under the ROC curve (bias-free, §2.5).
    Auc,
    /// Negative mean squared error (regression responses).
    NegMse,
}

/// One grid point's outcome.
#[derive(Clone, Debug)]
pub struct LambdaScore {
    pub lambda: f64,
    pub score: f64,
}

/// Result of a λ search.
#[derive(Clone, Debug)]
pub struct LambdaSearch {
    /// Scores per candidate, in input order.
    pub scores: Vec<LambdaScore>,
    /// Index of the winning candidate (ties → smaller λ).
    pub best: usize,
}

impl LambdaSearch {
    /// The selected ridge penalty.
    pub fn best_lambda(&self) -> f64 {
        self.scores[self.best].lambda
    }

    /// The winning score.
    pub fn best_score(&self) -> f64 {
        self.scores[self.best].score
    }
}

/// Log-spaced candidate grid (the usual default: 1e-3 … 1e3).
pub fn default_grid(points: usize) -> Vec<f64> {
    assert!(points >= 2);
    (0..points)
        .map(|i| 10f64.powf(-3.0 + 6.0 * i as f64 / (points - 1) as f64))
        .collect()
}

/// Search a λ grid with the analytic CV. `labels` drive Accuracy/AUC; for
/// `NegMse` the signed codes in `y` are treated as the regression target.
///
/// Backend is [`GramBackend::Auto`]: tall shapes share the primal gram
/// across the grid; wide shapes share one spectral decomposition, making
/// each additional candidate nearly free. Use [`search_lambda_backend`] to
/// force a backend (or [`search_lambda_ctx`] for a pooled context).
/// Errors when every candidate is infeasible.
///
/// ```
/// use fastcv::cv::folds::kfold;
/// use fastcv::data::synthetic::{generate, SyntheticSpec};
/// use fastcv::fastcv::lambda_search::{default_grid, search_lambda, SelectBy};
/// use fastcv::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let ds = generate(&SyntheticSpec::binary(30, 8), &mut rng);
/// let folds = kfold(30, 3, &mut rng);
/// let search = search_lambda(
///     &ds.x, &ds.y_signed(), &ds.labels, &folds, &default_grid(4), SelectBy::Accuracy,
/// ).unwrap();
/// assert_eq!(search.scores.len(), 4);
/// assert!(search.best_lambda() > 0.0);
/// ```
pub fn search_lambda(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
) -> Result<LambdaSearch> {
    search_lambda_backend(x, y, labels, folds, grid, by, GramBackend::Auto)
}

/// [`search_lambda`] with an explicit [`GramBackend`]. One [`GramCache`]
/// holds everything λ-free for the whole grid; per candidate only the
/// λ-dependent factor (primal/dual) or a diagonal rescale GEMM (spectral)
/// is paid. All backends select the identical winner up to roundoff
/// (property-tested).
pub fn search_lambda_backend(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
    backend: GramBackend,
) -> Result<LambdaSearch> {
    search_lambda_ctx(x, y, labels, folds, grid, by, &ComputeContext::serial().with_backend(backend))
}

/// [`search_lambda`] under a [`ComputeContext`]: the context's backend is
/// resolved for the grid and its pool (if any) fans out the shared Gram
/// build and each candidate's hat GEMM. A pooled context selects the
/// bit-identical winner with bit-identical scores — the pool is a pure
/// wall-clock knob (property-tested).
pub fn search_lambda_ctx(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
    ctx: &ComputeContext<'_>,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    let positives = grid.iter().filter(|&&l| l > 0.0).count();
    // Spill-aware: an Auto grid under `--spill-dir` resolves to the fully
    // streamable dual cache instead of a resident spectral one. The cache
    // itself comes through the context's FactorStore when one is lent
    // (keyed on data × resolved backend × tile — a hit serves the same
    // floats a fresh build would); without a store this is the historical
    // per-call build.
    let resolved = ctx.resolve_for_grid(x.rows(), x.cols(), positives);
    let cache = crate::store::gram_for_ctx(x, resolved, ctx)?;
    search_lambda_with_cache_tiled(&cache, y, labels, folds, grid, by, ctx.pool(), ctx.tile_policy())
}

/// The scoring loop of [`search_lambda`] against an already-built
/// [`GramCache`] — the λ-free state may come from anywhere: a plain
/// [`GramCache::build`], or a [`SharedNestedGram`] fold downdate (which is
/// how [`nested_cv_ctx`] shares one full-data Gram across outer folds).
pub fn search_lambda_with_cache(
    cache: &GramCache,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
    pool: Option<&ThreadPool>,
) -> Result<LambdaSearch> {
    search_lambda_with_cache_tiled(cache, y, labels, folds, grid, by, pool, TilePolicy::Off)
}

/// [`search_lambda_with_cache`] under a [`TilePolicy`]: each candidate's
/// dual `K_c + λI` Cholesky goes through the blocked in-place factor and
/// the per-fold `(I − H_Te)` LU factors fan out **fold-wise** over `pool`
/// ([`FoldCache::prepare_pool`]) — both bit-identical to their serial
/// forms, so scores and winner never move.
#[allow(clippy::too_many_arguments)]
pub fn search_lambda_with_cache_tiled(
    cache: &GramCache,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
    pool: Option<&ThreadPool>,
    tile: TilePolicy,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    // Structural fold errors (out-of-range index, overlap, empty test set)
    // are λ-independent caller bugs — surface them with their precise
    // message instead of letting every candidate score −∞ below.
    super::validate_folds(folds, cache.n())?;
    let mut scores = Vec::with_capacity(grid.len());
    for &lambda in grid {
        let score = match cache.hat_pool_tiled(lambda, pool, tile.clone()) {
            Ok(hat) => {
                let cv = AnalyticBinaryCv::with_hat(hat, y);
                match FoldCache::prepare_pool(&cv.hat, folds, false, pool) {
                    // a singular (I − H_Te) is λ-specific (the fold model
                    // itself is degenerate there) — score it out rather
                    // than abort a grid whose other candidates are fine,
                    // matching the multi-class search's handling.
                    Err(_) => f64::NEG_INFINITY,
                    Ok(fold_cache) => {
                        let dv = cv.decision_values_cached(&fold_cache);
                        match by {
                            SelectBy::Accuracy => accuracy_signed(&dv, y),
                            SelectBy::Auc => auc(&dv, labels),
                            SelectBy::NegMse => -crate::cv::metrics::mse(&dv, y),
                        }
                    }
                }
            }
            // λ infeasible for this shape/backend: worst score, not an abort.
            Err(_) => f64::NEG_INFINITY,
        };
        scores.push(LambdaScore { lambda, score });
    }
    let best = select_best(&scores)?;
    Ok(LambdaSearch { scores, best })
}

/// Multi-class λ selection through the analytic CV (the ROADMAP
/// "multi-class spectral λ-grid reuse" item): one [`GramCache`] — on wide
/// shapes one spectral decomposition — serves the entire grid exactly as in
/// the binary search, because step 1 of optimal scoring (the multivariate
/// ridge regression `Ŷ = HY`) is the only place λ and the features meet.
/// Per candidate the additional cost over the binary search is step 2's
/// `C×C` optimal-scores eigenproblem per fold — `O(C³)`, negligible.
///
/// Scores are cross-validated label accuracies
/// ([`AnalyticMulticlassCv::predict_cached`] + nearest-centroid). An
/// infeasible candidate (singular fold system) scores −∞; a grid with no
/// feasible candidate errors. Ties resolve to the smaller λ, matching
/// [`search_lambda`].
pub fn search_lambda_multiclass(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    grid: &[f64],
    ctx: &ComputeContext<'_>,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    let positives = grid.iter().filter(|&&l| l > 0.0).count();
    let resolved = ctx.resolve_for_grid(x.rows(), x.cols(), positives);
    // Store-aware fetch, same seam as `search_lambda_ctx`.
    let cache = crate::store::gram_for_ctx(x, resolved, ctx)?;
    search_lambda_multiclass_with_cache_tiled(
        &cache,
        labels,
        c,
        folds,
        grid,
        ctx.pool(),
        ctx.tile_policy(),
    )
}

/// The scoring loop of [`search_lambda_multiclass`] against an
/// already-built [`GramCache`].
pub fn search_lambda_multiclass_with_cache(
    cache: &GramCache,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    grid: &[f64],
    pool: Option<&ThreadPool>,
) -> Result<LambdaSearch> {
    search_lambda_multiclass_with_cache_tiled(cache, labels, c, folds, grid, pool, TilePolicy::Off)
}

/// [`search_lambda_multiclass_with_cache`] under a [`TilePolicy`] (see
/// [`search_lambda_with_cache_tiled`] — same blocked-Cholesky and
/// fold-wise fan-out, same bitwise contract).
pub fn search_lambda_multiclass_with_cache_tiled(
    cache: &GramCache,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    grid: &[f64],
    pool: Option<&ThreadPool>,
    tile: TilePolicy,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    // λ-independent fold-structure errors keep their precise message (see
    // search_lambda_with_cache).
    super::validate_folds(folds, cache.n())?;
    let mut scores = Vec::with_capacity(grid.len());
    for &lambda in grid {
        let score = match cache.hat_pool_tiled(lambda, pool, tile.clone()) {
            Ok(hat) => {
                let cv = AnalyticMulticlassCv::with_hat(hat, labels, c);
                match FoldCache::prepare_pool(&cv.hat, folds, true, pool) {
                    // a singular fold system is λ-specific — score it out
                    Err(_) => f64::NEG_INFINITY,
                    Ok(fold_cache) => {
                        let pred = cv.predict_cached(&fold_cache)?;
                        accuracy_labels(&pred, labels)
                    }
                }
            }
            Err(_) => f64::NEG_INFINITY,
        };
        scores.push(LambdaScore { lambda, score });
    }
    let best = select_best(&scores)?;
    Ok(LambdaSearch { scores, best })
}

/// Pick the winning grid index: highest score, ties → smaller λ (earlier
/// index). NaN orders as *worst* — below every real score and below the
/// −∞ of an infeasible fit — instead of poisoning the comparison (the old
/// `partial_cmp(..).unwrap()` aborted on the first NaN). When every
/// candidate is infeasible (NaN or −∞) there is nothing meaningful to
/// select and an error is returned.
pub(crate) fn select_best(scores: &[LambdaScore]) -> Result<usize> {
    let mut best: Option<usize> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.score.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if s.score > scores[b].score => best = Some(i),
            _ => {}
        }
    }
    match best {
        Some(b) if scores[b].score > f64::NEG_INFINITY => Ok(b),
        _ => anyhow::bail!(
            "λ search: every grid candidate is infeasible (score NaN or −∞) — \
             widen the grid, increase λ, or check the labels/metric"
        ),
    }
}

/// §2.6.2 convenience: search over a *shrinkage* grid by converting each
/// `λ_shrink ∈ [0,1)` to the equivalent ridge via Eq. 18 (`ν` from the
/// within-class scatter of the full data).
pub fn search_shrinkage(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    shrink_grid: &[f64],
    by: SelectBy,
) -> Result<(LambdaSearch, Vec<f64>)> {
    let sw = crate::stats::within_scatter(x, labels, 2);
    let nu = sw.trace() / x.cols() as f64;
    let ridge_grid: Vec<f64> = shrink_grid
        .iter()
        .map(|&ls| crate::model::Reg::shrinkage_to_ridge(ls, nu))
        .collect();
    Ok((search_lambda(x, y, labels, folds, &ridge_grid, by)?, ridge_grid))
}

/// Nested CV: outer folds estimate generalisation of the *whole pipeline*
/// (inner λ search included), the honest protocol for reporting tuned
/// performance. Returns (outer decision values, per-outer-fold chosen λ).
/// Inner searches run through [`GramBackend::Auto`] — on wide data each
/// outer fold pays one spectral decomposition for its whole inner grid.
pub fn nested_cv(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
) -> Result<(Vec<f64>, Vec<f64>)> {
    nested_cv_backend(x, y, labels, outer_folds, inner_k, grid, by, rng, GramBackend::Auto)
}

/// [`nested_cv`] with an explicit [`GramBackend`] for the inner searches.
#[allow(clippy::too_many_arguments)]
pub fn nested_cv_backend(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
    backend: GramBackend,
) -> Result<(Vec<f64>, Vec<f64>)> {
    nested_cv_ctx(
        x,
        y,
        labels,
        outer_folds,
        inner_k,
        grid,
        by,
        rng,
        &ComputeContext::serial().with_backend(backend),
    )
}

/// [`nested_cv`] under a [`ComputeContext`]. Beyond the pool fan-out, this
/// is where the context's nested-sharing knob
/// ([`ComputeContext::with_nested_sharing`]) pays off: outer training sets
/// overlap in all but one fold's worth of rows, so instead of rebuilding
/// each fold's centered Gram from the `P`-dimensional data (`O(N_tr²P)` per
/// outer fold), one full-data Gram `K = XXᵀ` is built **once** and each
/// fold's training Gram is *downdated* out of it by index selection +
/// re-centering (`O(N_tr²)`) — the Gram-level analogue of the paper's
/// Eq. 9–12 fold downdates (see [`SharedNestedGram`]). The per-fold
/// spectral decomposition then serves that fold's whole inner grid.
///
/// Sharing engages only when it is well-defined and profitable: the knob is
/// on **and** the grid/shape resolve to an `N×N` backend — `Spectral`
/// (wide data, ≥ 2 positive candidates; per-fold eigendecomposition) or
/// `Dual` (wide data, exactly one positive candidate; the downdated
/// `K[Tr,Tr]` feeds a single per-fold Cholesky instead of an `O(N_tr²P)`
/// rebuild — the ROADMAP "nested sharing for the dual backend" item). The
/// downdated Gram equals the rebuilt one in exact arithmetic but not
/// bitwise, so the default (knob off) reproduces [`nested_cv_backend`]
/// exactly; agreement between the modes is property-tested at tolerance.
#[allow(clippy::too_many_arguments)]
pub fn nested_cv_ctx(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
    ctx: &ComputeContext<'_>,
) -> Result<(Vec<f64>, Vec<f64>)> {
    super::validate_folds(outer_folds, x.rows())?;
    let positives = grid.iter().filter(|&&l| l > 0.0).count();
    // Share one full-data Gram across outer folds whenever every fold's
    // inner search stays on the N×N side anyway — `Spectral` (wide, ≥ 2
    // positive candidates) *or* `Dual` (wide, exactly one positive
    // candidate: the downdated K[Tr,Tr] feeds one per-fold Cholesky
    // instead of a rebuild). P > N_full implies P > N_tr for all training
    // subsets, so gating on the full shape is conservative.
    let resolved = ctx.resolve_for_grid(x.rows(), x.cols(), positives);
    let shared = if ctx.nested_sharing()
        && matches!(resolved, GramBackend::Spectral | GramBackend::Dual)
    {
        // Store-aware: with a FactorStore on the context the full-data
        // `XXᵀ` is fetched through the keyed cache (`ArtifactKind::Nested`)
        // — a repeated nested CV on the same data reuses the one `O(N²P)`
        // build; without a store this is the historical per-call build.
        Some(crate::store::nested_for_ctx(x, ctx)?)
    } else {
        None
    };
    let mut dvals = vec![f64::NAN; x.rows()];
    let mut chosen = Vec::with_capacity(outer_folds.len());
    for te in outer_folds {
        let tr = super::complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let inner_folds = crate::cv::folds::kfold(tr.len(), inner_k.min(tr.len()), rng);
        let search = match &shared {
            Some(gram) => {
                let cache = if resolved == GramBackend::Dual {
                    gram.fold_dual(&x_tr, &tr)?
                } else {
                    GramCache::Spectral(gram.fold_spectral(&x_tr, &tr)?)
                };
                search_lambda_with_cache_tiled(
                    &cache,
                    &y_tr,
                    &l_tr,
                    &inner_folds,
                    grid,
                    by,
                    ctx.pool(),
                    ctx.tile_policy(),
                )?
            }
            None => search_lambda_ctx(&x_tr, &y_tr, &l_tr, &inner_folds, grid, by, ctx)?,
        };
        let lambda = search.best_lambda();
        chosen.push(lambda);
        // Train on the full outer-training set with the chosen λ, predict Te.
        let model = crate::model::regression_lda::RegressionLda::train(&x_tr, &l_tr, lambda)?;
        let pred = model.decision_values_lr(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            dvals[i] = pred[j];
        }
    }
    Ok((dvals, chosen))
}

/// One hat for one λ — kept for API compatibility and the ablation bench's
/// "rebuild per candidate" arm. Grid sweeps should use [`GramCache`] (or
/// just [`search_lambda`]), which share everything λ-free instead.
pub fn hat_for_lambda(x: &Mat, lambda: f64) -> Result<HatMatrix> {
    HatMatrix::build(x, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::rng::Rng;

    #[test]
    fn grid_is_log_spaced() {
        let g = default_grid(7);
        assert_eq!(g.len(), 7);
        assert!((g[0] - 1e-3).abs() < 1e-12);
        assert!((g[6] - 1e3).abs() < 1e-9);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn wide_data_prefers_feasible_lambda() {
        // P ≫ N: λ=0 is singular (−∞ score); some positive λ wins with a
        // decent cross-validated accuracy. (Interestingly even tiny ridge
        // can interpolate well here — we assert feasibility + quality, not
        // a specific winner.)
        let mut rng = Rng::new(1);
        let mut spec = SyntheticSpec::binary(60, 300);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let grid = [0.0, 1e-2, 1.0, 100.0];
        let s = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 must be infeasible");
        assert!(s.best_lambda() > 0.0, "chose λ={}", s.best_lambda());
        assert!(s.best_score() > 0.7, "best acc={}", s.best_score());
    }

    #[test]
    fn auc_and_accuracy_selection_agree_roughly() {
        let mut rng = Rng::new(2);
        let mut spec = SyntheticSpec::binary(80, 40);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = default_grid(5);
        let a = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        let b = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Auc).unwrap();
        // same grid, correlated metrics: winners within a decade of each other
        let ratio = a.best_lambda() / b.best_lambda();
        assert!((0.01..=100.0).contains(&ratio), "acc λ={} auc λ={}", a.best_lambda(), b.best_lambda());
    }

    #[test]
    fn shrinkage_grid_converts_monotonically() {
        let mut rng = Rng::new(3);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let (search, ridge_grid) = search_shrinkage(
            &ds.x,
            &y,
            &ds.labels,
            &folds,
            &[0.01, 0.1, 0.5, 0.9],
            SelectBy::Accuracy,
        )
        .unwrap();
        assert_eq!(ridge_grid.len(), 4);
        for w in ridge_grid.windows(2) {
            assert!(w[1] > w[0], "Eq.18 is monotone in λ_shrink");
        }
        assert_eq!(search.scores.len(), 4);
    }

    #[test]
    fn nested_cv_returns_finite_dvals_and_reasonable_acc() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(60, 30);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let outer = stratified_kfold(&ds.labels, 4, &mut rng);
        let (dv, chosen) = nested_cv(
            &ds.x,
            &y,
            &ds.labels,
            &outer,
            3,
            &default_grid(4),
            SelectBy::Accuracy,
            &mut rng,
        )
        .unwrap();
        assert_eq!(chosen.len(), 4);
        assert!(dv.iter().all(|v| v.is_finite()));
        let acc = accuracy_signed(&dv, &y);
        assert!(acc > 0.7, "nested acc={acc}");
    }

    #[test]
    fn select_best_orders_nan_as_worst() {
        // Regression: the old `partial_cmp(..).unwrap()` aborted on the
        // first NaN score. NaN must lose to every real score — including a
        // lower one — and to −∞-feasible grids with any finite entry.
        let mk = |vals: &[f64]| -> Vec<LambdaScore> {
            vals.iter()
                .enumerate()
                .map(|(i, &score)| LambdaScore { lambda: i as f64, score })
                .collect()
        };
        assert_eq!(select_best(&mk(&[f64::NAN, 0.5])).unwrap(), 1);
        assert_eq!(select_best(&mk(&[0.2, f64::NAN, 0.1])).unwrap(), 0);
        assert_eq!(select_best(&mk(&[f64::NAN, 0.3, 0.3])).unwrap(), 1, "tie → smaller λ");
        assert_eq!(select_best(&mk(&[f64::NEG_INFINITY, f64::NAN, 0.1])).unwrap(), 2);
    }

    #[test]
    fn select_best_errors_when_every_candidate_is_infeasible() {
        // Regression: an all-infeasible grid used to silently "select" a λ.
        let mk = |vals: &[f64]| -> Vec<LambdaScore> {
            vals.iter()
                .enumerate()
                .map(|(i, &score)| LambdaScore { lambda: i as f64, score })
                .collect()
        };
        assert!(select_best(&mk(&[f64::NAN, f64::NAN])).is_err());
        assert!(select_best(&mk(&[f64::NEG_INFINITY])).is_err());
        assert!(select_best(&mk(&[f64::NEG_INFINITY, f64::NAN])).is_err());
    }

    #[test]
    fn all_infeasible_grid_returns_err_end_to_end() {
        // Wide data, grid containing only λ=0: every fit is singular, so
        // the search must refuse rather than return the useless λ=0.
        let mut rng = Rng::new(6);
        let ds = generate(&SyntheticSpec::binary(20, 80), &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let res = search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0], SelectBy::Accuracy);
        assert!(res.is_err(), "all-infeasible grid must error");
    }

    #[test]
    fn single_class_auc_grid_errors_not_panics() {
        // AUC is NaN for every λ when the labelling has one class; the
        // search must order those as worst and, with nothing feasible left,
        // error — the pre-fix code panicked inside the comparator.
        let mut rng = Rng::new(7);
        let x = crate::linalg::Mat::from_fn(20, 5, |_, _| rng.gauss());
        let labels = vec![0usize; 20];
        let y = vec![1.0; 20];
        let folds = crate::cv::folds::kfold(20, 4, &mut rng);
        let res = search_lambda(&x, &y, &labels, &folds, &default_grid(3), SelectBy::Auc);
        assert!(res.is_err(), "all-NaN AUC grid must error");
    }

    #[test]
    fn backend_equivalence_search_picks_identical_winner() {
        // Acceptance: primal, dual, and spectral backends must select the
        // same λ on the same grid — wide and tall shapes.
        use crate::fastcv::hat::GramBackend;
        let mut rng = Rng::new(8);
        for (n, p) in [(50usize, 150usize), (80, 20)] {
            let mut spec = SyntheticSpec::binary(n, p);
            spec.separation = 2.0;
            let ds = generate(&spec, &mut rng);
            let y = ds.y_signed();
            let folds = stratified_kfold(&ds.labels, 5, &mut rng);
            // Moderate ridges only: near-zero λ on wide shapes puts the
            // fold solves in the interpolation regime where backend
            // roundoff is amplified enough to flip a knife-edge accuracy.
            let grid = [0.1, 0.5, 2.0, 10.0, 50.0, 250.0];
            let runs: Vec<LambdaSearch> = [
                GramBackend::Primal,
                GramBackend::Dual,
                GramBackend::Spectral,
                GramBackend::Auto,
            ]
            .iter()
            .map(|&b| {
                search_lambda_backend(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, b)
                    .unwrap()
            })
            .collect();
            for r in &runs[1..] {
                assert_eq!(r.best, runs[0].best, "winner differs between backends (n={n} p={p})");
                assert!(
                    (r.best_score() - runs[0].best_score()).abs() < 1e-9,
                    "best score differs: {} vs {}",
                    r.best_score(),
                    runs[0].best_score()
                );
            }
        }
    }

    #[test]
    fn backend_pool_search_lambda_bitwise_matches_serial() {
        // A pooled context must reproduce the serial search bit-for-bit:
        // identical per-candidate scores and the identical winner, on both
        // the spectral (wide) and primal (tall) resolutions of Auto.
        let mut rng = Rng::new(41);
        for (n, p) in [(30usize, 90usize), (60, 15)] {
            let mut spec = SyntheticSpec::binary(n, p);
            spec.separation = 1.5;
            let ds = generate(&spec, &mut rng);
            let y = ds.y_signed();
            let folds = stratified_kfold(&ds.labels, 4, &mut rng);
            let grid = [0.1, 1.0, 10.0, 100.0];
            let serial = search_lambda_backend(
                &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Auto,
            )
            .unwrap();
            let ctx = crate::fastcv::ComputeContext::with_threads(4);
            let pooled =
                search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, &ctx)
                    .unwrap();
            assert_eq!(pooled.best, serial.best, "winner moved under a pool (n={n} p={p})");
            for (s, q) in serial.scores.iter().zip(&pooled.scores) {
                assert_eq!(s.score.to_bits(), q.score.to_bits(), "score moved (n={n} p={p})");
            }
        }
    }

    #[test]
    fn multiclass_search_agrees_with_per_lambda_rebuild() {
        // The tentpole reuse claim: one GramCache serving the whole grid
        // must score and select exactly like a from-scratch multi-class
        // rebuild per candidate — and the spectral cache must agree with
        // the primal one on the winner.
        use crate::fastcv::ComputeContext;
        use crate::model::lda_multiclass::tests::blobs;
        let mut rng = Rng::new(42);
        let (x, labels) = blobs(&mut rng, 10, 4, 90, 2.0); // N=40, P=90 (wide)
        let c = 4;
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let grid = [0.1, 1.0, 10.0, 100.0];
        // reference: per-λ rebuild through the historical primal fit
        let mut rebuild = Vec::new();
        for &l in &grid {
            let cv = crate::fastcv::multiclass::AnalyticMulticlassCv::fit(&x, &labels, c, l)
                .unwrap();
            let pred = cv.predict(&folds).unwrap();
            rebuild.push(crate::cv::metrics::accuracy_labels(&pred, &labels));
        }
        let primal = search_lambda_multiclass(
            &x,
            &labels,
            c,
            &folds,
            &grid,
            &ComputeContext::serial().with_backend(GramBackend::Primal),
        )
        .unwrap();
        for (s, &r) in primal.scores.iter().zip(&rebuild) {
            assert_eq!(s.score, r, "primal cache must reproduce the rebuild exactly");
        }
        let spectral = search_lambda_multiclass(
            &x,
            &labels,
            c,
            &folds,
            &grid,
            &ComputeContext::serial().with_backend(GramBackend::Spectral),
        )
        .unwrap();
        assert_eq!(spectral.best, primal.best, "spectral reuse picked a different λ");
        // predictions are backend-invariant (property-tested in multiclass),
        // so the 1/N-quantised accuracies must match exactly here too
        for (s, q) in primal.scores.iter().zip(&spectral.scores) {
            assert_eq!(s.score, q.score, "spectral score moved at λ={}", s.lambda);
        }
        // pooled context: bitwise identical to the serial spectral run
        let pooled = search_lambda_multiclass(
            &x,
            &labels,
            c,
            &folds,
            &grid,
            &ComputeContext::with_threads(4).with_backend(GramBackend::Spectral),
        )
        .unwrap();
        assert_eq!(pooled.best, spectral.best);
        for (s, q) in spectral.scores.iter().zip(&pooled.scores) {
            assert_eq!(s.score.to_bits(), q.score.to_bits());
        }
    }

    #[test]
    fn multiclass_search_all_infeasible_errors() {
        use crate::fastcv::ComputeContext;
        use crate::model::lda_multiclass::tests::blobs;
        let mut rng = Rng::new(43);
        let (x, labels) = blobs(&mut rng, 6, 3, 60, 2.0); // wide: λ=0 singular
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let res =
            search_lambda_multiclass(&x, &labels, 3, &folds, &[0.0], &ComputeContext::serial());
        assert!(res.is_err(), "all-infeasible multi-class grid must error");
    }

    #[test]
    fn nested_cv_shared_spectral_agrees_with_rebuild() {
        // The Eq. 9–12-style Gram sharing across outer folds must pick the
        // same λ per fold and produce decision values matching the per-fold
        // rebuild to tolerance (the downdate changes the float path, not
        // the math).
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(44);
        let mut spec = SyntheticSpec::binary(48, 160); // wide: spectral regime
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let outer = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = [0.5, 2.0, 10.0, 50.0];
        let run = |ctx: &ComputeContext, seed: u64| {
            nested_cv_ctx(
                &ds.x,
                &y,
                &ds.labels,
                &outer,
                3,
                &grid,
                SelectBy::Accuracy,
                &mut Rng::new(seed),
                ctx,
            )
            .unwrap()
        };
        let (dv_rebuild, lam_rebuild) = run(&ComputeContext::serial(), 9);
        let (dv_shared, lam_shared) = run(&ComputeContext::serial().with_nested_sharing(true), 9);
        assert_eq!(lam_shared, lam_rebuild, "shared mode picked different λs");
        for (a, b) in dv_rebuild.iter().zip(&dv_shared) {
            assert!((a - b).abs() < 1e-6, "dvals diverged: {a} vs {b}");
        }
        // pooled + shared is bitwise identical to serial + shared
        let (dv_pool, lam_pool) =
            run(&ComputeContext::with_threads(4).with_nested_sharing(true), 9);
        assert_eq!(lam_pool, lam_shared);
        for (a, b) in dv_shared.iter().zip(&dv_pool) {
            assert_eq!(a.to_bits(), b.to_bits(), "pool must be a pure wall-clock knob");
        }
        // default ctx (sharing off) reproduces nested_cv_backend bitwise
        let (dv_backend, lam_backend) = nested_cv_backend(
            &ds.x,
            &y,
            &ds.labels,
            &outer,
            3,
            &grid,
            SelectBy::Accuracy,
            &mut Rng::new(9),
            GramBackend::Auto,
        )
        .unwrap();
        assert_eq!(lam_backend, lam_rebuild);
        for (a, b) in dv_rebuild.iter().zip(&dv_backend) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn backend_nested_cv_dual_sharing_agrees_with_rebuild() {
        // The ROADMAP "nested sharing for the dual backend" item: on a
        // single-positive-λ grid over wide data, the shared full-data Gram
        // is downdated into one per-fold *Cholesky* (no eigendecomposition)
        // and must pick the same λ per fold with decision values matching
        // the per-fold rebuild to tolerance.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(46);
        let mut spec = SyntheticSpec::binary(40, 130); // wide: dual regime
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let outer = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = [2.0]; // exactly one positive candidate → Auto resolves Dual
        assert_eq!(
            GramBackend::Auto.resolve_for_grid(40, 130, 1),
            GramBackend::Dual,
            "precondition: this grid must resolve to the dual backend"
        );
        let run = |ctx: &ComputeContext, seed: u64| {
            nested_cv_ctx(
                &ds.x,
                &y,
                &ds.labels,
                &outer,
                3,
                &grid,
                SelectBy::Accuracy,
                &mut Rng::new(seed),
                ctx,
            )
            .unwrap()
        };
        let (dv_rebuild, lam_rebuild) = run(&ComputeContext::serial(), 5);
        let (dv_shared, lam_shared) = run(&ComputeContext::serial().with_nested_sharing(true), 5);
        assert_eq!(lam_shared, lam_rebuild, "dual sharing picked different λs");
        for (a, b) in dv_rebuild.iter().zip(&dv_shared) {
            assert!((a - b).abs() < 1e-6, "dvals diverged: {a} vs {b}");
        }
        // pooled + tiled + shared is bitwise identical to serial + shared
        let ctx = ComputeContext::with_threads(4)
            .with_nested_sharing(true)
            .with_tile_policy(crate::linalg::TilePolicy::Rows(8));
        let (dv_pool, lam_pool) = run(&ctx, 5);
        assert_eq!(lam_pool, lam_shared);
        for (a, b) in dv_shared.iter().zip(&dv_pool) {
            assert_eq!(a.to_bits(), b.to_bits(), "pool/tile must be pure wall-clock knobs");
        }
    }

    #[test]
    fn tiled_search_lambda_ctx_bitwise_matches_untiled() {
        // A tiled context must reproduce the untiled search bit-for-bit:
        // identical per-candidate scores and winner on both the spectral
        // (wide) and primal (tall) resolutions of Auto.
        use crate::fastcv::ComputeContext;
        use crate::linalg::TilePolicy;
        let mut rng = Rng::new(47);
        for (n, p) in [(24usize, 70usize), (50, 12)] {
            let mut spec = SyntheticSpec::binary(n, p);
            spec.separation = 1.5;
            let ds = generate(&spec, &mut rng);
            let y = ds.y_signed();
            let folds = stratified_kfold(&ds.labels, 4, &mut rng);
            let grid = [0.1, 1.0, 10.0];
            let untiled = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy)
                .unwrap();
            for tile in [TilePolicy::Rows(1), TilePolicy::Rows(7), TilePolicy::Rows(n + 3)] {
                let ctx = ComputeContext::with_threads(3).with_tile_policy(tile.clone());
                let tiled =
                    search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, &ctx)
                        .unwrap();
                assert_eq!(tiled.best, untiled.best, "winner moved (n={n} p={p} {tile:?})");
                for (s, q) in untiled.scores.iter().zip(&tiled.scores) {
                    assert_eq!(s.score.to_bits(), q.score.to_bits(), "score moved (n={n} p={p})");
                }
            }
        }
    }

    #[test]
    fn spill_search_lambda_ctx_bitwise_matches_untiled() {
        // A Spill policy must reproduce the in-RAM search bit-for-bit on
        // both out-of-core resolutions of Auto: the spilled primal cache
        // (tall shape) and the spilled dual cache (wide shape, exactly one
        // positive candidate).
        use crate::fastcv::ComputeContext;
        use crate::linalg::TilePolicy;
        let mut rng = Rng::new(55);
        // tall → PrimalSpill serves the whole grid
        let mut spec = SyntheticSpec::binary(40, 12);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = [0.1, 1.0, 10.0];
        let untiled =
            search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        let ctx = ComputeContext::with_threads(2)
            .with_tile_policy(TilePolicy::Spill { dir: None, tile: 5 });
        let spilled =
            search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, &ctx)
                .unwrap();
        assert_eq!(spilled.best, untiled.best, "primal-spill winner moved");
        for (s, q) in untiled.scores.iter().zip(&spilled.scores) {
            assert_eq!(s.score.to_bits(), q.score.to_bits(), "primal-spill score moved");
        }
        // wide + single positive λ → DualSpill
        let mut spec = SyntheticSpec::binary(24, 70);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = [1.0];
        assert_eq!(GramBackend::Auto.resolve_for_grid(24, 70, 1), GramBackend::Dual);
        let untiled =
            search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        let ctx = ComputeContext::serial()
            .with_tile_policy(TilePolicy::Spill { dir: None, tile: 7 });
        let spilled =
            search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, &ctx)
                .unwrap();
        for (s, q) in untiled.scores.iter().zip(&spilled.scores) {
            assert_eq!(s.score.to_bits(), q.score.to_bits(), "dual-spill score moved");
        }
        // wide + multi-λ Auto under Spill: the spill-aware resolution picks
        // the fully-streamable dual cache (not a resident spectral one) —
        // scores equal an explicit in-RAM Dual search bitwise, and the
        // winner agrees with the spectral run (backend-equivalence grid).
        let grid = [0.5, 2.0, 10.0, 50.0];
        let dual_ref = search_lambda_backend(
            &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Dual,
        )
        .unwrap();
        let spilled =
            search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, &ctx)
                .unwrap();
        for (s, q) in dual_ref.scores.iter().zip(&spilled.scores) {
            assert_eq!(s.score.to_bits(), q.score.to_bits(), "auto-spill grid score moved");
        }
        let spectral = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy)
            .unwrap();
        assert_eq!(spilled.best, spectral.best, "auto-spill winner diverged from spectral");
    }

    #[test]
    fn fold_singular_lambda_scores_neg_infinity_not_error() {
        // N=12, P=8, 2 folds: the full-data gram is fine at λ=0 (N > P+1)
        // but each training fold has 6 samples for 9 coefficients, so the
        // fold model is degenerate and (I − H_Te) is exactly singular.
        // That λ must be scored out (−∞), not abort the grid — the λ>0
        // candidates are perfectly feasible.
        let mut rng = Rng::new(51);
        let ds = generate(&SyntheticSpec::binary(12, 8), &mut rng);
        let y = ds.y_signed();
        let folds = vec![(0..6).collect::<Vec<_>>(), (6..12).collect::<Vec<_>>()];
        let s = search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0, 1.0], SelectBy::Accuracy)
            .unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "fold-singular λ=0 scored out");
        assert_eq!(s.best_lambda(), 1.0);
    }

    #[test]
    fn infeasible_lambda_scores_neg_infinity_not_error() {
        let mut rng = Rng::new(5);
        let ds = generate(&SyntheticSpec::binary(20, 100), &mut rng); // P ≫ N
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let s =
            search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0, 1.0], SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 infeasible on wide data");
        assert_eq!(s.best_lambda(), 1.0);
    }
}
