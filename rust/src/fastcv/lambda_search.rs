//! Ridge-parameter selection by analytic cross-validation.
//!
//! The classic pain of regularised LDA is that tuning λ multiplies the CV
//! cost by the grid size. With the analytic approach the gram matrix
//! `X̃ᵀX̃` is computed **once**; each λ candidate costs one factorisation of
//! `G + λI₀` plus the `O(N²P)` hat build and the fold solves — no per-fold
//! refits anywhere. This module implements that loop, plus the §2.6.2
//! shrinkage-grid convenience through the Eq. 18 conversion.

use super::binary::AnalyticBinaryCv;
use super::hat::HatMatrix;
use super::FoldCache;
use crate::cv::metrics::{accuracy_signed, auc};
use crate::linalg::Mat;
use anyhow::Result;

/// Model-selection metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectBy {
    /// Classification accuracy of the signed decision values.
    Accuracy,
    /// Area under the ROC curve (bias-free, §2.5).
    Auc,
    /// Negative mean squared error (regression responses).
    NegMse,
}

/// One grid point's outcome.
#[derive(Clone, Debug)]
pub struct LambdaScore {
    pub lambda: f64,
    pub score: f64,
}

/// Result of a λ search.
#[derive(Clone, Debug)]
pub struct LambdaSearch {
    /// Scores per candidate, in input order.
    pub scores: Vec<LambdaScore>,
    /// Index of the winning candidate (ties → smaller λ).
    pub best: usize,
}

impl LambdaSearch {
    /// The selected ridge penalty.
    pub fn best_lambda(&self) -> f64 {
        self.scores[self.best].lambda
    }

    /// The winning score.
    pub fn best_score(&self) -> f64 {
        self.scores[self.best].score
    }
}

/// Log-spaced candidate grid (the usual default: 1e-3 … 1e3).
pub fn default_grid(points: usize) -> Vec<f64> {
    assert!(points >= 2);
    (0..points)
        .map(|i| 10f64.powf(-3.0 + 6.0 * i as f64 / (points - 1) as f64))
        .collect()
}

/// Search a λ grid with the analytic CV. `labels` drive Accuracy/AUC; for
/// `NegMse` the signed codes in `y` are treated as the regression target.
pub fn search_lambda(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    let mut scores = Vec::with_capacity(grid.len());
    for &lambda in grid {
        // Each λ: fresh hat (G factor + O(N²P) build), shared gram inputs.
        let score = match AnalyticBinaryCv::fit(x, y, lambda) {
            Ok(cv) => {
                let cache = FoldCache::prepare(&cv.hat, folds, false)?;
                let dv = cv.decision_values_cached(&cache);
                match by {
                    SelectBy::Accuracy => accuracy_signed(&dv, y),
                    SelectBy::Auc => auc(&dv, labels),
                    SelectBy::NegMse => -crate::cv::metrics::mse(&dv, y),
                }
            }
            // λ too small for a wide design: worst score, not an abort.
            Err(_) => f64::NEG_INFINITY,
        };
        scores.push(LambdaScore { lambda, score });
    }
    let best = scores
        .iter()
        .enumerate()
        .max_by(|(ia, a), (ib, b)| {
            a.score
                .partial_cmp(&b.score)
                .unwrap()
                .then(ib.cmp(ia)) // tie → smaller λ (earlier index)
        })
        .map(|(i, _)| i)
        .unwrap();
    Ok(LambdaSearch { scores, best })
}

/// §2.6.2 convenience: search over a *shrinkage* grid by converting each
/// `λ_shrink ∈ [0,1)` to the equivalent ridge via Eq. 18 (`ν` from the
/// within-class scatter of the full data).
pub fn search_shrinkage(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    shrink_grid: &[f64],
    by: SelectBy,
) -> Result<(LambdaSearch, Vec<f64>)> {
    let sw = crate::stats::within_scatter(x, labels, 2);
    let nu = sw.trace() / x.cols() as f64;
    let ridge_grid: Vec<f64> = shrink_grid
        .iter()
        .map(|&ls| crate::model::Reg::shrinkage_to_ridge(ls, nu))
        .collect();
    Ok((search_lambda(x, y, labels, folds, &ridge_grid, by)?, ridge_grid))
}

/// Nested CV: outer folds estimate generalisation of the *whole pipeline*
/// (inner λ search included), the honest protocol for reporting tuned
/// performance. Returns (outer decision values, per-outer-fold chosen λ).
pub fn nested_cv(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
) -> Result<(Vec<f64>, Vec<f64>)> {
    super::validate_folds(outer_folds, x.rows())?;
    let mut dvals = vec![f64::NAN; x.rows()];
    let mut chosen = Vec::with_capacity(outer_folds.len());
    for te in outer_folds {
        let tr = super::complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let inner_folds = crate::cv::folds::kfold(tr.len(), inner_k.min(tr.len()), rng);
        let search = search_lambda(&x_tr, &y_tr, &l_tr, &inner_folds, grid, by)?;
        let lambda = search.best_lambda();
        chosen.push(lambda);
        // Train on the full outer-training set with the chosen λ, predict Te.
        let model = crate::model::regression_lda::RegressionLda::train(&x_tr, &l_tr, lambda)?;
        let pred = model.decision_values_lr(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            dvals[i] = pred[j];
        }
    }
    Ok((dvals, chosen))
}

/// Reuse a gram factor across λ values? The gram itself is λ-free; expose
/// the build so callers sweeping huge grids can at least share `X̃ᵀX̃`.
/// (Kept simple: HatMatrix::build recomputes the gram; this helper exists
/// so the ablation bench can quantify what sharing would save.)
pub fn hat_for_lambda(x: &Mat, lambda: f64) -> Result<HatMatrix> {
    HatMatrix::build(x, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::rng::Rng;

    #[test]
    fn grid_is_log_spaced() {
        let g = default_grid(7);
        assert_eq!(g.len(), 7);
        assert!((g[0] - 1e-3).abs() < 1e-12);
        assert!((g[6] - 1e3).abs() < 1e-9);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn wide_data_prefers_feasible_lambda() {
        // P ≫ N: λ=0 is singular (−∞ score); some positive λ wins with a
        // decent cross-validated accuracy. (Interestingly even tiny ridge
        // can interpolate well here — we assert feasibility + quality, not
        // a specific winner.)
        let mut rng = Rng::new(1);
        let mut spec = SyntheticSpec::binary(60, 300);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let grid = [0.0, 1e-2, 1.0, 100.0];
        let s = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 must be infeasible");
        assert!(s.best_lambda() > 0.0, "chose λ={}", s.best_lambda());
        assert!(s.best_score() > 0.7, "best acc={}", s.best_score());
    }

    #[test]
    fn auc_and_accuracy_selection_agree_roughly() {
        let mut rng = Rng::new(2);
        let mut spec = SyntheticSpec::binary(80, 40);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = default_grid(5);
        let a = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        let b = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Auc).unwrap();
        // same grid, correlated metrics: winners within a decade of each other
        let ratio = a.best_lambda() / b.best_lambda();
        assert!((0.01..=100.0).contains(&ratio), "acc λ={} auc λ={}", a.best_lambda(), b.best_lambda());
    }

    #[test]
    fn shrinkage_grid_converts_monotonically() {
        let mut rng = Rng::new(3);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let (search, ridge_grid) = search_shrinkage(
            &ds.x,
            &y,
            &ds.labels,
            &folds,
            &[0.01, 0.1, 0.5, 0.9],
            SelectBy::Accuracy,
        )
        .unwrap();
        assert_eq!(ridge_grid.len(), 4);
        for w in ridge_grid.windows(2) {
            assert!(w[1] > w[0], "Eq.18 is monotone in λ_shrink");
        }
        assert_eq!(search.scores.len(), 4);
    }

    #[test]
    fn nested_cv_returns_finite_dvals_and_reasonable_acc() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(60, 30);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let outer = stratified_kfold(&ds.labels, 4, &mut rng);
        let (dv, chosen) = nested_cv(
            &ds.x,
            &y,
            &ds.labels,
            &outer,
            3,
            &default_grid(4),
            SelectBy::Accuracy,
            &mut rng,
        )
        .unwrap();
        assert_eq!(chosen.len(), 4);
        assert!(dv.iter().all(|v| v.is_finite()));
        let acc = accuracy_signed(&dv, &y);
        assert!(acc > 0.7, "nested acc={acc}");
    }

    #[test]
    fn infeasible_lambda_scores_neg_infinity_not_error() {
        let mut rng = Rng::new(5);
        let ds = generate(&SyntheticSpec::binary(20, 100), &mut rng); // P ≫ N
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let s =
            search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0, 1.0], SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 infeasible on wide data");
        assert_eq!(s.best_lambda(), 1.0);
    }
}
