//! Ridge-parameter selection by analytic cross-validation.
//!
//! The classic pain of regularised LDA is that tuning λ multiplies the CV
//! cost by the grid size. The analytic approach shares everything λ-free
//! across the grid through a [`GramCache`]: the primal path computes the
//! gram `X̃ᵀX̃` **once** (each candidate pays only the factorisation and the
//! hat GEMM), and on wide (P ≫ N) shapes the spectral path goes further —
//! one eigendecomposition of the centered `N×N` Gram after which every
//! candidate is a single `O(N³)` GEMM, no `O(P³)` anywhere. No per-fold
//! refits in any case. This module implements that loop, plus the §2.6.2
//! shrinkage-grid convenience through the Eq. 18 conversion.
//!
//! Selection is NaN-safe: an undefined metric (NaN — e.g. AUC on a
//! single-class labelling) orders below every real score *and* below the
//! −∞ of an infeasible fit, and a grid on which **every** candidate is
//! infeasible returns an error instead of silently "selecting" a λ.

use super::binary::AnalyticBinaryCv;
use super::hat::{GramBackend, GramCache, HatMatrix};
use super::FoldCache;
use crate::cv::metrics::{accuracy_signed, auc};
use crate::linalg::Mat;
use anyhow::Result;

/// Model-selection metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectBy {
    /// Classification accuracy of the signed decision values.
    Accuracy,
    /// Area under the ROC curve (bias-free, §2.5).
    Auc,
    /// Negative mean squared error (regression responses).
    NegMse,
}

/// One grid point's outcome.
#[derive(Clone, Debug)]
pub struct LambdaScore {
    pub lambda: f64,
    pub score: f64,
}

/// Result of a λ search.
#[derive(Clone, Debug)]
pub struct LambdaSearch {
    /// Scores per candidate, in input order.
    pub scores: Vec<LambdaScore>,
    /// Index of the winning candidate (ties → smaller λ).
    pub best: usize,
}

impl LambdaSearch {
    /// The selected ridge penalty.
    pub fn best_lambda(&self) -> f64 {
        self.scores[self.best].lambda
    }

    /// The winning score.
    pub fn best_score(&self) -> f64 {
        self.scores[self.best].score
    }
}

/// Log-spaced candidate grid (the usual default: 1e-3 … 1e3).
pub fn default_grid(points: usize) -> Vec<f64> {
    assert!(points >= 2);
    (0..points)
        .map(|i| 10f64.powf(-3.0 + 6.0 * i as f64 / (points - 1) as f64))
        .collect()
}

/// Search a λ grid with the analytic CV. `labels` drive Accuracy/AUC; for
/// `NegMse` the signed codes in `y` are treated as the regression target.
///
/// Backend is [`GramBackend::Auto`]: tall shapes share the primal gram
/// across the grid; wide shapes share one spectral decomposition, making
/// each additional candidate nearly free. Use [`search_lambda_backend`] to
/// force a backend. Errors when every candidate is infeasible.
pub fn search_lambda(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
) -> Result<LambdaSearch> {
    search_lambda_backend(x, y, labels, folds, grid, by, GramBackend::Auto)
}

/// [`search_lambda`] with an explicit [`GramBackend`]. One [`GramCache`]
/// holds everything λ-free for the whole grid; per candidate only the
/// λ-dependent factor (primal/dual) or a diagonal rescale GEMM (spectral)
/// is paid. All backends select the identical winner up to roundoff
/// (property-tested).
pub fn search_lambda_backend(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    grid: &[f64],
    by: SelectBy,
    backend: GramBackend,
) -> Result<LambdaSearch> {
    assert!(!grid.is_empty());
    let positives = grid.iter().filter(|&&l| l > 0.0).count();
    let resolved = backend.resolve_for_grid(x.rows(), x.cols(), positives);
    let cache = GramCache::build(x, resolved, None);
    let mut scores = Vec::with_capacity(grid.len());
    for &lambda in grid {
        let score = match cache.hat(lambda) {
            Ok(hat) => {
                let cv = AnalyticBinaryCv::with_hat(hat, y);
                let fold_cache = FoldCache::prepare(&cv.hat, folds, false)?;
                let dv = cv.decision_values_cached(&fold_cache);
                match by {
                    SelectBy::Accuracy => accuracy_signed(&dv, y),
                    SelectBy::Auc => auc(&dv, labels),
                    SelectBy::NegMse => -crate::cv::metrics::mse(&dv, y),
                }
            }
            // λ infeasible for this shape/backend: worst score, not an abort.
            Err(_) => f64::NEG_INFINITY,
        };
        scores.push(LambdaScore { lambda, score });
    }
    let best = select_best(&scores)?;
    Ok(LambdaSearch { scores, best })
}

/// Pick the winning grid index: highest score, ties → smaller λ (earlier
/// index). NaN orders as *worst* — below every real score and below the
/// −∞ of an infeasible fit — instead of poisoning the comparison (the old
/// `partial_cmp(..).unwrap()` aborted on the first NaN). When every
/// candidate is infeasible (NaN or −∞) there is nothing meaningful to
/// select and an error is returned.
pub(crate) fn select_best(scores: &[LambdaScore]) -> Result<usize> {
    let mut best: Option<usize> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.score.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if s.score > scores[b].score => best = Some(i),
            _ => {}
        }
    }
    match best {
        Some(b) if scores[b].score > f64::NEG_INFINITY => Ok(b),
        _ => anyhow::bail!(
            "λ search: every grid candidate is infeasible (score NaN or −∞) — \
             widen the grid, increase λ, or check the labels/metric"
        ),
    }
}

/// §2.6.2 convenience: search over a *shrinkage* grid by converting each
/// `λ_shrink ∈ [0,1)` to the equivalent ridge via Eq. 18 (`ν` from the
/// within-class scatter of the full data).
pub fn search_shrinkage(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    folds: &[Vec<usize>],
    shrink_grid: &[f64],
    by: SelectBy,
) -> Result<(LambdaSearch, Vec<f64>)> {
    let sw = crate::stats::within_scatter(x, labels, 2);
    let nu = sw.trace() / x.cols() as f64;
    let ridge_grid: Vec<f64> = shrink_grid
        .iter()
        .map(|&ls| crate::model::Reg::shrinkage_to_ridge(ls, nu))
        .collect();
    Ok((search_lambda(x, y, labels, folds, &ridge_grid, by)?, ridge_grid))
}

/// Nested CV: outer folds estimate generalisation of the *whole pipeline*
/// (inner λ search included), the honest protocol for reporting tuned
/// performance. Returns (outer decision values, per-outer-fold chosen λ).
/// Inner searches run through [`GramBackend::Auto`] — on wide data each
/// outer fold pays one spectral decomposition for its whole inner grid.
pub fn nested_cv(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
) -> Result<(Vec<f64>, Vec<f64>)> {
    nested_cv_backend(x, y, labels, outer_folds, inner_k, grid, by, rng, GramBackend::Auto)
}

/// [`nested_cv`] with an explicit [`GramBackend`] for the inner searches.
#[allow(clippy::too_many_arguments)]
pub fn nested_cv_backend(
    x: &Mat,
    y: &[f64],
    labels: &[usize],
    outer_folds: &[Vec<usize>],
    inner_k: usize,
    grid: &[f64],
    by: SelectBy,
    rng: &mut crate::util::rng::Rng,
    backend: GramBackend,
) -> Result<(Vec<f64>, Vec<f64>)> {
    super::validate_folds(outer_folds, x.rows())?;
    let mut dvals = vec![f64::NAN; x.rows()];
    let mut chosen = Vec::with_capacity(outer_folds.len());
    for te in outer_folds {
        let tr = super::complement(te, x.rows());
        let x_tr = x.take_rows(&tr);
        let y_tr: Vec<f64> = tr.iter().map(|&i| y[i]).collect();
        let l_tr: Vec<usize> = tr.iter().map(|&i| labels[i]).collect();
        let inner_folds = crate::cv::folds::kfold(tr.len(), inner_k.min(tr.len()), rng);
        let search = search_lambda_backend(&x_tr, &y_tr, &l_tr, &inner_folds, grid, by, backend)?;
        let lambda = search.best_lambda();
        chosen.push(lambda);
        // Train on the full outer-training set with the chosen λ, predict Te.
        let model = crate::model::regression_lda::RegressionLda::train(&x_tr, &l_tr, lambda)?;
        let pred = model.decision_values_lr(&x.take_rows(te));
        for (j, &i) in te.iter().enumerate() {
            dvals[i] = pred[j];
        }
    }
    Ok((dvals, chosen))
}

/// One hat for one λ — kept for API compatibility and the ablation bench's
/// "rebuild per candidate" arm. Grid sweeps should use [`GramCache`] (or
/// just [`search_lambda`]), which share everything λ-free instead.
pub fn hat_for_lambda(x: &Mat, lambda: f64) -> Result<HatMatrix> {
    HatMatrix::build(x, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::rng::Rng;

    #[test]
    fn grid_is_log_spaced() {
        let g = default_grid(7);
        assert_eq!(g.len(), 7);
        assert!((g[0] - 1e-3).abs() < 1e-12);
        assert!((g[6] - 1e3).abs() < 1e-9);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn wide_data_prefers_feasible_lambda() {
        // P ≫ N: λ=0 is singular (−∞ score); some positive λ wins with a
        // decent cross-validated accuracy. (Interestingly even tiny ridge
        // can interpolate well here — we assert feasibility + quality, not
        // a specific winner.)
        let mut rng = Rng::new(1);
        let mut spec = SyntheticSpec::binary(60, 300);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let grid = [0.0, 1e-2, 1.0, 100.0];
        let s = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 must be infeasible");
        assert!(s.best_lambda() > 0.0, "chose λ={}", s.best_lambda());
        assert!(s.best_score() > 0.7, "best acc={}", s.best_score());
    }

    #[test]
    fn auc_and_accuracy_selection_agree_roughly() {
        let mut rng = Rng::new(2);
        let mut spec = SyntheticSpec::binary(80, 40);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let grid = default_grid(5);
        let a = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();
        let b = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Auc).unwrap();
        // same grid, correlated metrics: winners within a decade of each other
        let ratio = a.best_lambda() / b.best_lambda();
        assert!((0.01..=100.0).contains(&ratio), "acc λ={} auc λ={}", a.best_lambda(), b.best_lambda());
    }

    #[test]
    fn shrinkage_grid_converts_monotonically() {
        let mut rng = Rng::new(3);
        let ds = generate(&SyntheticSpec::binary(50, 20), &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);
        let (search, ridge_grid) = search_shrinkage(
            &ds.x,
            &y,
            &ds.labels,
            &folds,
            &[0.01, 0.1, 0.5, 0.9],
            SelectBy::Accuracy,
        )
        .unwrap();
        assert_eq!(ridge_grid.len(), 4);
        for w in ridge_grid.windows(2) {
            assert!(w[1] > w[0], "Eq.18 is monotone in λ_shrink");
        }
        assert_eq!(search.scores.len(), 4);
    }

    #[test]
    fn nested_cv_returns_finite_dvals_and_reasonable_acc() {
        let mut rng = Rng::new(4);
        let mut spec = SyntheticSpec::binary(60, 30);
        spec.separation = 2.0;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let outer = stratified_kfold(&ds.labels, 4, &mut rng);
        let (dv, chosen) = nested_cv(
            &ds.x,
            &y,
            &ds.labels,
            &outer,
            3,
            &default_grid(4),
            SelectBy::Accuracy,
            &mut rng,
        )
        .unwrap();
        assert_eq!(chosen.len(), 4);
        assert!(dv.iter().all(|v| v.is_finite()));
        let acc = accuracy_signed(&dv, &y);
        assert!(acc > 0.7, "nested acc={acc}");
    }

    #[test]
    fn select_best_orders_nan_as_worst() {
        // Regression: the old `partial_cmp(..).unwrap()` aborted on the
        // first NaN score. NaN must lose to every real score — including a
        // lower one — and to −∞-feasible grids with any finite entry.
        let mk = |vals: &[f64]| -> Vec<LambdaScore> {
            vals.iter()
                .enumerate()
                .map(|(i, &score)| LambdaScore { lambda: i as f64, score })
                .collect()
        };
        assert_eq!(select_best(&mk(&[f64::NAN, 0.5])).unwrap(), 1);
        assert_eq!(select_best(&mk(&[0.2, f64::NAN, 0.1])).unwrap(), 0);
        assert_eq!(select_best(&mk(&[f64::NAN, 0.3, 0.3])).unwrap(), 1, "tie → smaller λ");
        assert_eq!(select_best(&mk(&[f64::NEG_INFINITY, f64::NAN, 0.1])).unwrap(), 2);
    }

    #[test]
    fn select_best_errors_when_every_candidate_is_infeasible() {
        // Regression: an all-infeasible grid used to silently "select" a λ.
        let mk = |vals: &[f64]| -> Vec<LambdaScore> {
            vals.iter()
                .enumerate()
                .map(|(i, &score)| LambdaScore { lambda: i as f64, score })
                .collect()
        };
        assert!(select_best(&mk(&[f64::NAN, f64::NAN])).is_err());
        assert!(select_best(&mk(&[f64::NEG_INFINITY])).is_err());
        assert!(select_best(&mk(&[f64::NEG_INFINITY, f64::NAN])).is_err());
    }

    #[test]
    fn all_infeasible_grid_returns_err_end_to_end() {
        // Wide data, grid containing only λ=0: every fit is singular, so
        // the search must refuse rather than return the useless λ=0.
        let mut rng = Rng::new(6);
        let ds = generate(&SyntheticSpec::binary(20, 80), &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let res = search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0], SelectBy::Accuracy);
        assert!(res.is_err(), "all-infeasible grid must error");
    }

    #[test]
    fn single_class_auc_grid_errors_not_panics() {
        // AUC is NaN for every λ when the labelling has one class; the
        // search must order those as worst and, with nothing feasible left,
        // error — the pre-fix code panicked inside the comparator.
        let mut rng = Rng::new(7);
        let x = crate::linalg::Mat::from_fn(20, 5, |_, _| rng.gauss());
        let labels = vec![0usize; 20];
        let y = vec![1.0; 20];
        let folds = crate::cv::folds::kfold(20, 4, &mut rng);
        let res = search_lambda(&x, &y, &labels, &folds, &default_grid(3), SelectBy::Auc);
        assert!(res.is_err(), "all-NaN AUC grid must error");
    }

    #[test]
    fn backend_equivalence_search_picks_identical_winner() {
        // Acceptance: primal, dual, and spectral backends must select the
        // same λ on the same grid — wide and tall shapes.
        use crate::fastcv::hat::GramBackend;
        let mut rng = Rng::new(8);
        for (n, p) in [(50usize, 150usize), (80, 20)] {
            let mut spec = SyntheticSpec::binary(n, p);
            spec.separation = 2.0;
            let ds = generate(&spec, &mut rng);
            let y = ds.y_signed();
            let folds = stratified_kfold(&ds.labels, 5, &mut rng);
            // Moderate ridges only: near-zero λ on wide shapes puts the
            // fold solves in the interpolation regime where backend
            // roundoff is amplified enough to flip a knife-edge accuracy.
            let grid = [0.1, 0.5, 2.0, 10.0, 50.0, 250.0];
            let runs: Vec<LambdaSearch> = [
                GramBackend::Primal,
                GramBackend::Dual,
                GramBackend::Spectral,
                GramBackend::Auto,
            ]
            .iter()
            .map(|&b| {
                search_lambda_backend(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, b)
                    .unwrap()
            })
            .collect();
            for r in &runs[1..] {
                assert_eq!(r.best, runs[0].best, "winner differs between backends (n={n} p={p})");
                assert!(
                    (r.best_score() - runs[0].best_score()).abs() < 1e-9,
                    "best score differs: {} vs {}",
                    r.best_score(),
                    runs[0].best_score()
                );
            }
        }
    }

    #[test]
    fn infeasible_lambda_scores_neg_infinity_not_error() {
        let mut rng = Rng::new(5);
        let ds = generate(&SyntheticSpec::binary(20, 100), &mut rng); // P ≫ N
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let s =
            search_lambda(&ds.x, &y, &ds.labels, &folds, &[0.0, 1.0], SelectBy::Accuracy).unwrap();
        assert_eq!(s.scores[0].score, f64::NEG_INFINITY, "λ=0 infeasible on wide data");
        assert_eq!(s.best_lambda(), 1.0);
    }
}
