//! Batched, thread-parallel permutation testing (the GEMM formulation of
//! §2.7 / Alg. 1 & 2).
//!
//! The serial engine in [`super::perm`] already reuses the hat matrix and
//! the per-fold `(I − H_Te)` LU factors across permutations, but it still
//! walks permutations one at a time: a matvec `ŷ = H·y^σ` plus `K`
//! single-RHS triangular solves per permutation. Following the Gram-level
//! batching of Engström & Jensen (2024, *Fast Partition-Based
//! Cross-Validation*), this module stacks `B` permuted responses into an
//! `N×B` matrix `Y^σ` and turns the whole per-permutation stream into
//! matrix-level kernels:
//!
//! - `Ŷ = H·Y^σ` — one GEMM per batch instead of `B` matvecs;
//! - `Ė_Te = (I−H_Te)⁻¹ Ê_Te` — one multi-RHS [`crate::linalg::Lu::solve_mat`]
//!   per fold over all `B` columns;
//! - Eq. 15 / Alg. 2 cross-terms `H_{Tr,Te}·Ė_Te` — one GEMM per fold.
//!
//! Batches are independent, so they fan out across the
//! [`ThreadPool`](crate::util::threadpool::ThreadPool) via
//! [`BatchStrategy::threads`].
//!
//! ## Determinism
//!
//! Permutation `t` is derived from the counter-seeded stream
//! [`Rng::stream`]`(anchor, t)` (see [`super::perm::permuted_labels`] and
//! the contract in [`super::perm`]'s module docs), where the anchor is the
//! single `u64` drawn from the caller's RNG — exactly as the serial engine
//! draws it. The null distribution is therefore **bit-identical** to the
//! serial engine's for any batch size and thread count: per-permutation
//! arithmetic goes through kernels whose per-column results do not depend
//! on the batch width (GEMM and the multi-RHS solves process columns as
//! independent lanes), and the multi-class step 2 runs through the very
//! same per-fold code as the serial path.

use super::binary::AnalyticBinaryCv;
use super::context::ComputeContext;
use super::hat::GramBackend;
use super::multiclass::AnalyticMulticlassCv;
use super::perm::{p_value, permuted_labels, PermutationResult};
use super::FoldCache;
use crate::cv::metrics::{accuracy_labels, accuracy_signed};
use crate::linalg::Mat;
use crate::model::lda_binary::signed_codes;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;

/// How the batched engine partitions and parallelises the permutation
/// stream. Neither knob changes results — only wall-clock (see the
/// determinism notes in the module docs).
///
/// Pool lifetime: when more than one batch exists and `threads > 1`, each
/// engine call spawns (and joins) its own short-lived
/// [`ThreadPool`](crate::util::threadpool::ThreadPool) — unless the call
/// went through a `_ctx` entry point whose [`ComputeContext`] already
/// holds a pool, in which case that pool is borrowed for the batch
/// fan-out too (one pool serves hat build and batches). Spawn cost is a
/// few hundred microseconds — negligible against a multi-batch permutation
/// stream, and single-batch runs (`n_perm ≤ batch_size`) never spawn a
/// pool at all.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchStrategy {
    /// Permutations per response matrix (`B`); the GEMM/multi-RHS width.
    pub batch_size: usize,
    /// Worker threads batches fan out over (1 = run on the caller thread).
    pub threads: usize,
}

impl Default for BatchStrategy {
    fn default() -> Self {
        BatchStrategy { batch_size: 64, threads: 1 }
    }
}

impl BatchStrategy {
    /// Explicit batch size and thread count (`threads` is floored at 1).
    pub fn new(batch_size: usize, threads: usize) -> BatchStrategy {
        assert!(batch_size > 0, "batch_size must be ≥ 1");
        BatchStrategy { batch_size, threads: threads.max(1) }
    }

    /// Batch of 64, one worker per logical core (capped at 16).
    pub fn auto() -> BatchStrategy {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
        BatchStrategy { batch_size: 64, threads }
    }
}

/// Split `0..n_perm` into `(start, len)` batches of at most `batch_size`.
fn batch_ranges(n_perm: usize, batch_size: usize) -> Vec<(usize, usize)> {
    assert!(batch_size > 0);
    let mut out = Vec::with_capacity(n_perm.div_ceil(batch_size));
    let mut start = 0;
    while start < n_perm {
        let len = batch_size.min(n_perm - start);
        out.push((start, len));
        // lint:allow(float_accum, reason = "integer batch offset accumulation — exact arithmetic")
        start += len;
    }
    out
}

/// Run every batch (serially or across a pool), concatenating the
/// per-permutation accuracies in permutation-index order.
///
/// When the caller already holds a pool (a [`ComputeContext`] with one),
/// it is borrowed for the batch fan-out instead of spawning a second,
/// mostly-redundant pool next to it; otherwise a short-lived pool of
/// `threads` workers is spawned as before. Either way results are
/// bit-identical — batch evaluation order never affects values.
fn run_batches<F>(
    batches: &[(usize, usize)],
    threads: usize,
    borrowed: Option<&ThreadPool>,
    run: F,
) -> Result<Vec<f64>>
where
    F: Fn(usize, usize) -> Result<Vec<f64>> + Send + Sync,
{
    let fan_out = |pool: &ThreadPool| {
        pool.map(batches.len(), |i| {
            let (start, len) = batches[i];
            run(start, len)
        })
    };
    let per_batch: Vec<Result<Vec<f64>>> = if threads <= 1 || batches.len() <= 1 {
        batches.iter().map(|&(start, len)| run(start, len)).collect()
    } else if let Some(pool) = borrowed {
        fan_out(pool)
    } else {
        fan_out(&ThreadPool::new(threads.min(batches.len())))
    };
    let mut null = Vec::new();
    for r in per_batch {
        null.extend(r?);
    }
    Ok(null)
}

/// Batched analytic binary permutation test (Algorithm 1, GEMM form).
///
/// Same contract as [`super::perm::analytic_binary_permutation`] — identical
/// observed value, null distribution, and p-value for an RNG in the same
/// state — at a fraction of the wall-clock (see `benches/ablation_updates.rs`).
/// Like the serial engine, the default backend is [`GramBackend::Auto`]
/// (per-shape hat build; null distributions are backend-invariant, pinned
/// by the golden contract).
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_batched(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
    strategy: BatchStrategy,
) -> Result<PermutationResult> {
    analytic_binary_permutation_batched_backend(
        x,
        labels,
        folds,
        lambda,
        n_perm,
        bias_adjust,
        rng,
        strategy,
        GramBackend::Auto,
    )
}

/// [`analytic_binary_permutation_batched`] with an explicit
/// [`GramBackend`] for the one-off hat build. For equal backends the null
/// distribution stays bit-identical to the serial engine's (the hat is
/// shared; batching only regroups the downstream kernels).
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_batched_backend(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
    strategy: BatchStrategy,
    backend: GramBackend,
) -> Result<PermutationResult> {
    analytic_binary_permutation_batched_ctx(
        x,
        labels,
        folds,
        lambda,
        n_perm,
        bias_adjust,
        rng,
        strategy,
        &ComputeContext::serial().with_backend(backend),
    )
}

/// [`analytic_binary_permutation_batched`] under a [`ComputeContext`]: the
/// context's pool fans out the one-off hat build **and**, when
/// `strategy.threads > 1`, is borrowed for the batch fan-out (one pool
/// serves both phases instead of two pools sitting half-idle). Neither
/// axis moves a bit of the null distribution.
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_batched_ctx(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    bias_adjust: bool,
    rng: &mut Rng,
    strategy: BatchStrategy,
    ctx: &ComputeContext<'_>,
) -> Result<PermutationResult> {
    let y = signed_codes(labels);
    let cv = AnalyticBinaryCv::fit_ctx(x, &y, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, bias_adjust, ctx.pool())?;
    let observed = if bias_adjust {
        accuracy_signed(&cv.decision_values_bias_adjusted(&cache, labels)?, &y)
    } else {
        accuracy_signed(&cv.decision_values_cached(&cache), &y)
    };
    let anchor = rng.next_u64();
    let n = labels.len();
    let run = |start: usize, len: usize| -> Result<Vec<f64>> {
        // Y^σ: one column per permutation in this batch.
        let mut labels_cols: Vec<Vec<usize>> = Vec::with_capacity(len);
        let mut ys = Mat::zeros(n, len);
        for col in 0..len {
            let labels_perm = permuted_labels(labels, anchor, (start + col) as u64);
            let codes = signed_codes(&labels_perm);
            for (i, &v) in codes.iter().enumerate() {
                ys[(i, col)] = v;
            }
            labels_cols.push(labels_perm);
        }
        let dvals = if bias_adjust {
            cv.decision_values_bias_adjusted_mat(&cache, &ys, &labels_cols)?
        } else {
            cv.decision_values_cached_mat(&cache, &ys)
        };
        let mut accs = Vec::with_capacity(len);
        for col in 0..len {
            let dv: Vec<f64> = (0..n).map(|i| dvals[(i, col)]).collect();
            let yc: Vec<f64> = (0..n).map(|i| ys[(i, col)]).collect();
            accs.push(accuracy_signed(&dv, &yc));
        }
        Ok(accs)
    };
    let null = run_batches(&batch_ranges(n_perm, strategy.batch_size), strategy.threads, ctx.pool(), run)?;
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

/// Batched analytic multi-class permutation test (Algorithm 2, GEMM form).
///
/// Step 1 of every permutation in a batch runs as stacked matrix kernels
/// (`N × B·C` responses); step 2 reuses the serial per-fold code, so the
/// null distribution is bit-identical to
/// [`super::perm::analytic_multiclass_permutation`] for an RNG in the same
/// state. Default backend [`GramBackend::Auto`], like every engine.
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_batched(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
    strategy: BatchStrategy,
) -> Result<PermutationResult> {
    analytic_multiclass_permutation_batched_backend(
        x,
        labels,
        c,
        folds,
        lambda,
        n_perm,
        rng,
        strategy,
        GramBackend::Auto,
    )
}

/// [`analytic_multiclass_permutation_batched`] with an explicit
/// [`GramBackend`].
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_batched_backend(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
    strategy: BatchStrategy,
    backend: GramBackend,
) -> Result<PermutationResult> {
    analytic_multiclass_permutation_batched_ctx(
        x,
        labels,
        c,
        folds,
        lambda,
        n_perm,
        rng,
        strategy,
        &ComputeContext::serial().with_backend(backend),
    )
}

/// [`analytic_multiclass_permutation_batched`] under a [`ComputeContext`]
/// (the context's pool serves the one-off hat build and, when
/// `strategy.threads > 1`, the batch fan-out; bit-identical results
/// either way).
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_batched_ctx(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    n_perm: usize,
    rng: &mut Rng,
    strategy: BatchStrategy,
    ctx: &ComputeContext<'_>,
) -> Result<PermutationResult> {
    let cv = AnalyticMulticlassCv::fit_ctx(x, labels, c, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, true, ctx.pool())?;
    let observed = accuracy_labels(&cv.predict_cached(&cache)?, labels);
    let anchor = rng.next_u64();
    let n = labels.len();
    let run = |start: usize, len: usize| -> Result<Vec<f64>> {
        let labels_cols: Vec<Vec<usize>> = (0..len)
            .map(|col| permuted_labels(labels, anchor, (start + col) as u64))
            .collect();
        // Stacked indicator block: permutation p owns columns p·C..(p+1)·C.
        let mut y_stack = Mat::zeros(n, len * c);
        for (p, labels_perm) in labels_cols.iter().enumerate() {
            for (i, &l) in labels_perm.iter().enumerate() {
                y_stack[(i, p * c + l)] = 1.0;
            }
        }
        let preds = cv.predict_cached_stacked(&cache, &y_stack, &labels_cols)?;
        Ok(preds
            .iter()
            .zip(&labels_cols)
            .map(|(pred, labels_perm)| accuracy_labels(pred, labels_perm))
            .collect())
    };
    let null = run_batches(&batch_ranges(n_perm, strategy.batch_size), strategy.threads, ctx.pool(), run)?;
    Ok(PermutationResult { observed, p_value: p_value(observed, &null), null })
}

/// One queued permutation request inside a coalesced engine pass: the
/// request's determinism anchor plus its permutation count.
///
/// The anchor is the single `u64` the request's RNG would have produced
/// before permuting (the serve layer computes it as
/// `Rng::new(seed).next_u64()` — the exact draw
/// [`analytic_binary_permutation_batched_ctx`] makes from a fresh
/// `Rng::new(seed)`, since fit and fold prep consume no randomness), so a
/// job's permutation `t` derives from `Rng::stream(anchor, t)` exactly as
/// a standalone run derives it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PermJob {
    /// Determinism anchor: the one `u64` drawn from the request's RNG.
    pub anchor: u64,
    /// Number of permutations this request asked for.
    pub n_perm: usize,
}

/// Prefix offsets of the jobs' permutation counts: `offsets[j]` is the
/// first global column owned by job `j`, `offsets[jobs.len()]` the total.
fn job_offsets(jobs: &[PermJob]) -> Vec<usize> {
    let mut offsets = Vec::with_capacity(jobs.len() + 1);
    offsets.push(0usize);
    for job in jobs {
        offsets.push(offsets[offsets.len() - 1] + job.n_perm);
    }
    offsets
}

/// Map a global permutation column to `(job index, local permutation t)`.
fn job_of(offsets: &[usize], global: usize) -> (usize, usize) {
    let j = offsets.partition_point(|&o| o <= global) - 1;
    (j, global - offsets[j])
}

/// Slice the concatenated null back into per-job [`PermutationResult`]s.
fn split_jobs(null_all: &[f64], offsets: &[usize], observed: f64) -> Vec<PermutationResult> {
    offsets
        .windows(2)
        .map(|w| {
            let null = null_all[w[0]..w[1]].to_vec();
            PermutationResult { observed, p_value: p_value(observed, &null), null }
        })
        .collect()
}

/// Coalesced analytic binary permutation testing: several queued requests
/// on the **same** (data, folds, λ, bias-adjust) key run as one engine
/// pass — one hat build, one [`FoldCache`], one observed accuracy, and one
/// permutation stream whose GEMM batches span every request's columns.
///
/// Job `j`'s permutation `t` uses `Rng::stream(jobs[j].anchor, t)` exactly
/// as a standalone run would, and the batched kernels process columns as
/// independent lanes (the module-docs determinism contract), so result `j`
/// is **bit-identical** to running that request alone through
/// [`analytic_binary_permutation_batched_ctx`] with an RNG whose first
/// draw is `jobs[j].anchor` — for any batch size, thread count, or job
/// interleaving (property-tested below). This is the `fastcv serve`
/// coalescing engine: merging M concurrent requests on one key costs one
/// hat build instead of M.
#[allow(clippy::too_many_arguments)]
pub fn analytic_binary_permutation_jobs_ctx(
    x: &Mat,
    labels: &[usize],
    folds: &[Vec<usize>],
    lambda: f64,
    jobs: &[PermJob],
    bias_adjust: bool,
    strategy: BatchStrategy,
    ctx: &ComputeContext<'_>,
) -> Result<Vec<PermutationResult>> {
    let y = signed_codes(labels);
    let cv = AnalyticBinaryCv::fit_ctx(x, &y, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, bias_adjust, ctx.pool())?;
    let observed = if bias_adjust {
        accuracy_signed(&cv.decision_values_bias_adjusted(&cache, labels)?, &y)
    } else {
        accuracy_signed(&cv.decision_values_cached(&cache), &y)
    };
    let offsets = job_offsets(jobs);
    let total = offsets[jobs.len()];
    let n = labels.len();
    let run = |start: usize, len: usize| -> Result<Vec<f64>> {
        let mut labels_cols: Vec<Vec<usize>> = Vec::with_capacity(len);
        let mut ys = Mat::zeros(n, len);
        for col in 0..len {
            let (j, t) = job_of(&offsets, start + col);
            let labels_perm = permuted_labels(labels, jobs[j].anchor, t as u64);
            let codes = signed_codes(&labels_perm);
            for (i, &v) in codes.iter().enumerate() {
                ys[(i, col)] = v;
            }
            labels_cols.push(labels_perm);
        }
        let dvals = if bias_adjust {
            cv.decision_values_bias_adjusted_mat(&cache, &ys, &labels_cols)?
        } else {
            cv.decision_values_cached_mat(&cache, &ys)
        };
        let mut accs = Vec::with_capacity(len);
        for col in 0..len {
            let dv: Vec<f64> = (0..n).map(|i| dvals[(i, col)]).collect();
            let yc: Vec<f64> = (0..n).map(|i| ys[(i, col)]).collect();
            accs.push(accuracy_signed(&dv, &yc));
        }
        Ok(accs)
    };
    let null_all =
        run_batches(&batch_ranges(total, strategy.batch_size), strategy.threads, ctx.pool(), run)?;
    Ok(split_jobs(&null_all, &offsets, observed))
}

/// Coalesced analytic multi-class permutation testing — the Algorithm 2
/// sibling of [`analytic_binary_permutation_jobs_ctx`], with the same
/// contract: one fit + fold prep serves every job, and result `j` is
/// bit-identical to a standalone
/// [`analytic_multiclass_permutation_batched_ctx`] run whose RNG's first
/// draw is `jobs[j].anchor`.
#[allow(clippy::too_many_arguments)]
pub fn analytic_multiclass_permutation_jobs_ctx(
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
    jobs: &[PermJob],
    strategy: BatchStrategy,
    ctx: &ComputeContext<'_>,
) -> Result<Vec<PermutationResult>> {
    let cv = AnalyticMulticlassCv::fit_ctx(x, labels, c, lambda, ctx)?;
    let cache = FoldCache::prepare_pool(&cv.hat, folds, true, ctx.pool())?;
    let observed = accuracy_labels(&cv.predict_cached(&cache)?, labels);
    let offsets = job_offsets(jobs);
    let total = offsets[jobs.len()];
    let n = labels.len();
    let run = |start: usize, len: usize| -> Result<Vec<f64>> {
        let labels_cols: Vec<Vec<usize>> = (0..len)
            .map(|col| {
                let (j, t) = job_of(&offsets, start + col);
                permuted_labels(labels, jobs[j].anchor, t as u64)
            })
            .collect();
        // Stacked indicator block: batch column p owns p·C..(p+1)·C.
        let mut y_stack = Mat::zeros(n, len * c);
        for (p, labels_perm) in labels_cols.iter().enumerate() {
            for (i, &l) in labels_perm.iter().enumerate() {
                y_stack[(i, p * c + l)] = 1.0;
            }
        }
        let preds = cv.predict_cached_stacked(&cache, &y_stack, &labels_cols)?;
        Ok(preds
            .iter()
            .zip(&labels_cols)
            .map(|(pred, labels_perm)| accuracy_labels(pred, labels_perm))
            .collect())
    };
    let null_all =
        run_batches(&batch_ranges(total, strategy.batch_size), strategy.threads, ctx.pool(), run)?;
    Ok(split_jobs(&null_all, &offsets, observed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::stratified_kfold;
    use crate::fastcv::perm::{analytic_binary_permutation, analytic_multiclass_permutation};
    use crate::model::lda_multiclass::tests::blobs;
    use crate::util::prop::Cases;

    /// The tentpole invariant: identical observed, null (±1e-12, which for
    /// accuracies means identical correct-counts), and p-value.
    fn assert_same_result(a: &PermutationResult, b: &PermutationResult, what: &str) {
        assert!(
            (a.observed - b.observed).abs() <= 1e-12,
            "{what}: observed {} vs {}",
            a.observed,
            b.observed
        );
        assert_eq!(a.null.len(), b.null.len(), "{what}: null length");
        for (i, (x, y)) in a.null.iter().zip(&b.null).enumerate() {
            assert!((x - y).abs() <= 1e-12, "{what}: null[{i}] {x} vs {y}");
        }
        assert!((a.p_value - b.p_value).abs() <= 1e-12, "{what}: p-value");
    }

    const CONFIGS: [(usize, usize); 5] = [(1, 1), (7, 1), (64, 1), (7, 3), (16, 4)];

    #[test]
    fn batched_binary_bit_identical_to_serial() {
        // Property test across shapes, fold counts, ridge values, bias
        // adjustment, batch sizes, and thread counts.
        Cases::new(10).run("binary batched == serial", |rng| {
            let per = 8 + rng.below(10);
            let p = 2 + rng.below(12);
            let (x, labels) = blobs(rng, per, 2, p, 2.0);
            let k = 2 + rng.below(4);
            let folds = stratified_kfold(&labels, k, rng);
            let lambda = 10f64.powf(rng.uniform_in(-2.0, 1.0));
            let n_perm = 1 + rng.below(25);
            let bias_adjust = rng.below(2) == 1;
            let seed = rng.next_u64();
            let serial = match analytic_binary_permutation(
                &x,
                &labels,
                &folds,
                lambda,
                n_perm,
                bias_adjust,
                &mut Rng::new(seed),
            ) {
                Ok(r) => r,
                Err(_) => return, // degenerate fold draw — valid skip
            };
            for (batch_size, threads) in CONFIGS {
                let batched = analytic_binary_permutation_batched(
                    &x,
                    &labels,
                    &folds,
                    lambda,
                    n_perm,
                    bias_adjust,
                    &mut Rng::new(seed),
                    BatchStrategy::new(batch_size, threads),
                )
                .unwrap();
                assert_same_result(
                    &serial,
                    &batched,
                    &format!("binary B={batch_size} T={threads} bias={bias_adjust}"),
                );
            }
        });
    }

    #[test]
    fn batched_multiclass_bit_identical_to_serial() {
        Cases::new(6).run("multiclass batched == serial", |rng| {
            let c = 3 + rng.below(2);
            let per = 8 + rng.below(6);
            let p = 2 + rng.below(10);
            let (x, labels) = blobs(rng, per, c, p, 2.0);
            let k = 3 + rng.below(3);
            let folds = stratified_kfold(&labels, k, rng);
            let lambda = 10f64.powf(rng.uniform_in(-1.5, 1.0));
            let n_perm = 1 + rng.below(12);
            let seed = rng.next_u64();
            let serial = match analytic_multiclass_permutation(
                &x,
                &labels,
                c,
                &folds,
                lambda,
                n_perm,
                &mut Rng::new(seed),
            ) {
                Ok(r) => r,
                Err(_) => return, // degenerate permutation draw — valid skip
            };
            for (batch_size, threads) in CONFIGS {
                let batched = analytic_multiclass_permutation_batched(
                    &x,
                    &labels,
                    c,
                    &folds,
                    lambda,
                    n_perm,
                    &mut Rng::new(seed),
                    BatchStrategy::new(batch_size, threads),
                )
                .unwrap();
                assert_same_result(
                    &serial,
                    &batched,
                    &format!("multiclass B={batch_size} T={threads}"),
                );
            }
        });
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // Direct batched-vs-batched check at a fixed batch size: the pool
        // fan-out must be pure bookkeeping.
        let mut rng = Rng::new(11);
        let (x, labels) = blobs(&mut rng, 15, 2, 8, 2.5);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let base = analytic_binary_permutation_batched(
            &x,
            &labels,
            &folds,
            0.5,
            40,
            false,
            &mut Rng::new(99),
            BatchStrategy::new(8, 1),
        )
        .unwrap();
        for threads in [2, 4, 8] {
            let t = analytic_binary_permutation_batched(
                &x,
                &labels,
                &folds,
                0.5,
                40,
                false,
                &mut Rng::new(99),
                BatchStrategy::new(8, threads),
            )
            .unwrap();
            assert_eq!(base.null, t.null, "threads={threads} must be bit-identical");
            assert_eq!(base.p_value, t.p_value);
        }
    }

    #[test]
    fn backend_equivalence_batched_engine_bit_identical_per_backend() {
        // For a fixed backend the batched engine must stay bit-identical to
        // the serial engine (the hat is shared, batching is regrouping) —
        // including through the dual backend on a wide shape.
        use crate::fastcv::perm::analytic_binary_permutation_backend;
        let mut rng = Rng::new(17);
        let (x, labels) = blobs(&mut rng, 10, 2, 50, 2.0); // N=20, P=50
        let folds = stratified_kfold(&labels, 4, &mut rng);
        for backend in [GramBackend::Dual, GramBackend::Spectral] {
            let serial = analytic_binary_permutation_backend(
                &x, &labels, &folds, 0.8, 12, false, &mut Rng::new(5), backend,
            )
            .unwrap();
            let batched = analytic_binary_permutation_batched_backend(
                &x,
                &labels,
                &folds,
                0.8,
                12,
                false,
                &mut Rng::new(5),
                BatchStrategy::new(5, 2),
                backend,
            )
            .unwrap();
            assert_same_result(&serial, &batched, &format!("backend {backend:?}"));
        }
    }

    #[test]
    fn backend_pool_batched_engine_bitwise_matches_serial_ctx() {
        // Hat-build pool (ctx) and batch pool (strategy) compose without
        // moving a bit: serial-ctx serial-batch == pooled-ctx threaded-batch.
        use crate::fastcv::ComputeContext;
        let mut rng = Rng::new(29);
        let (x, labels) = blobs(&mut rng, 12, 2, 60, 2.0); // wide
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let base = analytic_binary_permutation_batched_backend(
            &x,
            &labels,
            &folds,
            0.9,
            20,
            true,
            &mut Rng::new(3),
            BatchStrategy::new(7, 1),
            GramBackend::Spectral,
        )
        .unwrap();
        let ctx = ComputeContext::with_threads(4).with_backend(GramBackend::Spectral);
        let pooled = analytic_binary_permutation_batched_ctx(
            &x,
            &labels,
            &folds,
            0.9,
            20,
            true,
            &mut Rng::new(3),
            BatchStrategy::new(7, 3),
            &ctx,
        )
        .unwrap();
        assert_eq!(pooled.observed, base.observed);
        assert_eq!(pooled.null, base.null);
        assert_eq!(pooled.p_value, base.p_value);
        // multi-class engine
        let (x, labels) = blobs(&mut rng, 9, 3, 40, 2.0);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let base = analytic_multiclass_permutation_batched_backend(
            &x,
            &labels,
            3,
            &folds,
            1.2,
            9,
            &mut Rng::new(4),
            BatchStrategy::new(4, 1),
            GramBackend::Dual,
        )
        .unwrap();
        let ctx = ComputeContext::with_threads(3).with_backend(GramBackend::Dual);
        let pooled = analytic_multiclass_permutation_batched_ctx(
            &x,
            &labels,
            3,
            &folds,
            1.2,
            9,
            &mut Rng::new(4),
            BatchStrategy::new(4, 2),
            &ctx,
        )
        .unwrap();
        assert_eq!(pooled.observed, base.observed);
        assert_eq!(pooled.null, base.null);
    }

    #[test]
    fn coalesced_jobs_bit_identical_to_standalone_runs() {
        // Acceptance property for the serve coalescing engine: merging two
        // requests into one jobs pass returns, per job, exactly the null
        // distribution / p-value a standalone batched run with that job's
        // seed produces — bitwise, across bias adjustment, batch size, and
        // thread count (batch boundaries differ between the merged and
        // standalone runs, so this also re-proves lane independence).
        let mut rng = Rng::new(41);
        let (x, labels) = blobs(&mut rng, 12, 2, 30, 2.0);
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let lambda = 0.6;
        let seeds = [900u64, 901];
        let n_perms = [11usize, 17];
        for bias_adjust in [false, true] {
            let solo: Vec<PermutationResult> = seeds
                .iter()
                .zip(n_perms)
                .map(|(&s, np)| {
                    analytic_binary_permutation_batched_ctx(
                        &x,
                        &labels,
                        &folds,
                        lambda,
                        np,
                        bias_adjust,
                        &mut Rng::new(s),
                        BatchStrategy::new(6, 1),
                        &ComputeContext::serial(),
                    )
                    .unwrap()
                })
                .collect();
            // The serve layer's anchor: the first draw of the request RNG,
            // exactly what the batched engine draws post-fit.
            let jobs: Vec<PermJob> = seeds
                .iter()
                .zip(n_perms)
                .map(|(&s, np)| PermJob { anchor: Rng::new(s).next_u64(), n_perm: np })
                .collect();
            for (batch, threads) in [(10usize, 1usize), (4, 3), (64, 2)] {
                let merged = analytic_binary_permutation_jobs_ctx(
                    &x,
                    &labels,
                    &folds,
                    lambda,
                    &jobs,
                    bias_adjust,
                    BatchStrategy::new(batch, threads),
                    &ComputeContext::serial(),
                )
                .unwrap();
                assert_eq!(merged.len(), 2);
                for (m, s) in merged.iter().zip(&solo) {
                    assert_eq!(m.observed, s.observed, "bias={bias_adjust} B={batch}");
                    assert_eq!(m.null, s.null, "bias={bias_adjust} B={batch} T={threads}");
                    assert_eq!(m.p_value, s.p_value);
                }
            }
        }
    }

    #[test]
    fn coalesced_multiclass_jobs_bit_identical_to_standalone_runs() {
        let mut rng = Rng::new(43);
        let c = 3;
        let (x, labels) = blobs(&mut rng, 9, c, 24, 2.5);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let lambda = 1.1;
        let seeds = [77u64, 78, 79];
        let n_perms = [5usize, 9, 1];
        let solo: Vec<PermutationResult> = seeds
            .iter()
            .zip(n_perms)
            .map(|(&s, np)| {
                analytic_multiclass_permutation_batched_ctx(
                    &x,
                    &labels,
                    c,
                    &folds,
                    lambda,
                    np,
                    &mut Rng::new(s),
                    BatchStrategy::new(4, 1),
                    &ComputeContext::serial(),
                )
                .unwrap()
            })
            .collect();
        let jobs: Vec<PermJob> = seeds
            .iter()
            .zip(n_perms)
            .map(|(&s, np)| PermJob { anchor: Rng::new(s).next_u64(), n_perm: np })
            .collect();
        let merged = analytic_multiclass_permutation_jobs_ctx(
            &x,
            &labels,
            c,
            &folds,
            lambda,
            &jobs,
            BatchStrategy::new(7, 2),
            &ComputeContext::serial(),
        )
        .unwrap();
        assert_eq!(merged.len(), 3);
        for (j, (m, s)) in merged.iter().zip(&solo).enumerate() {
            assert_eq!(m.observed, s.observed, "job {j}");
            assert_eq!(m.null, s.null, "job {j}");
            assert_eq!(m.p_value, s.p_value, "job {j}");
        }
        // Degenerate shapes: no jobs, and a zero-permutation job.
        let empty = analytic_binary_permutation_jobs_ctx(
            &x,
            &labels,
            &folds,
            lambda,
            &[],
            false,
            BatchStrategy::default(),
            &ComputeContext::serial(),
        )
        .unwrap();
        assert!(empty.is_empty());
        let zero = analytic_binary_permutation_jobs_ctx(
            &x,
            &labels,
            &folds,
            lambda,
            &[PermJob { anchor: 1, n_perm: 0 }],
            false,
            BatchStrategy::default(),
            &ComputeContext::serial(),
        )
        .unwrap();
        assert_eq!(zero.len(), 1);
        assert!(zero[0].null.is_empty());
        assert_eq!(zero[0].p_value, 1.0);
    }

    #[test]
    fn job_offsets_and_mapping_cover_exactly() {
        let jobs = [
            PermJob { anchor: 1, n_perm: 3 },
            PermJob { anchor: 2, n_perm: 0 },
            PermJob { anchor: 3, n_perm: 2 },
        ];
        let offsets = job_offsets(&jobs);
        assert_eq!(offsets, vec![0, 3, 3, 5]);
        assert_eq!(job_of(&offsets, 0), (0, 0));
        assert_eq!(job_of(&offsets, 2), (0, 2));
        // global 3 skips the empty job and lands on job 2's first perm
        assert_eq!(job_of(&offsets, 3), (2, 0));
        assert_eq!(job_of(&offsets, 4), (2, 1));
        assert_eq!(job_offsets(&[]), vec![0]);
    }

    #[test]
    fn zero_permutations_gives_p_one() {
        let mut rng = Rng::new(3);
        let (x, labels) = blobs(&mut rng, 10, 2, 4, 2.0);
        let folds = stratified_kfold(&labels, 3, &mut rng);
        let r = analytic_binary_permutation_batched(
            &x,
            &labels,
            &folds,
            0.5,
            0,
            false,
            &mut rng,
            BatchStrategy::default(),
        )
        .unwrap();
        assert!(r.null.is_empty());
        assert_eq!(r.p_value, 1.0);
    }

    #[test]
    fn batch_ranges_cover_exactly() {
        assert_eq!(batch_ranges(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(batch_ranges(3, 64), vec![(0, 3)]);
        assert!(batch_ranges(0, 8).is_empty());
        let ranges = batch_ranges(1000, 64);
        let total: usize = ranges.iter().map(|&(_, l)| l).sum();
        assert_eq!(total, 1000);
        for w in ranges.windows(2) {
            assert_eq!(w[0].0 + w[0].1, w[1].0, "contiguous");
        }
    }

    #[test]
    fn strategy_constructors() {
        assert_eq!(BatchStrategy::default(), BatchStrategy { batch_size: 64, threads: 1 });
        assert_eq!(BatchStrategy::new(8, 0).threads, 1, "threads floored at 1");
        assert!(BatchStrategy::auto().threads >= 1);
    }

    #[test]
    fn backend_golden_null_distributions_recorded_for_default_flip() {
        // Backend-aware perm defaults, **step 2** (ROADMAP): the engines'
        // implicit backend is now `Auto`. This fixed-seed contract is what
        // made the flip safe, over an (N, P) grid covering both Auto
        // resolutions:
        //
        //   1. the golden reference is the serial engine under `Primal` at
        //      a pinned anchor seed — the *historical* default, so the flip
        //      is proven not to re-anchor any recorded null;
        //   2. all four engines — serial/batched × binary/multiclass —
        //      reproduce it bit-for-bit under every explicit backend (the
        //      hat is shared per run and accuracies are 1/N-quantised, so
        //      the ~1e-9 hat roundoff cannot move them at these λ);
        //   3. the *default* entry points are pinned to that same golden
        //      **and** the backend `Auto` resolves to is asserted per
        //      shape: `Dual` on the wide grids (the flip's payoff — the
        //      one-off hat build drops from O(NP²+P³) to O(N²P+N³)),
        //      `Primal` on the tall ones (where nothing changes).
        use crate::fastcv::perm::{
            analytic_binary_permutation_backend, analytic_multiclass_permutation_backend,
        };
        let backends = [GramBackend::Primal, GramBackend::Dual, GramBackend::Spectral];
        // Fixed-seed grid: (samples-per-class, P) with wide and tall shapes.
        for &(per, p, seed) in &[(8usize, 40usize, 401u64), (12, 6, 402)] {
            let mut rng = Rng::new(seed);
            let (x, labels) = blobs(&mut rng, per, 2, p, 2.0);
            let folds = stratified_kfold(&labels, 4, &mut rng);
            // What the flipped default actually builds with, per shape.
            let wide = p > labels.len();
            let resolved = GramBackend::Auto.resolve(labels.len(), p, 1.0);
            assert_eq!(
                resolved,
                if wide { GramBackend::Dual } else { GramBackend::Primal },
                "Auto resolution moved (N={}, P={p})",
                labels.len()
            );
            let anchor = 1234 + seed;
            let golden = analytic_binary_permutation_backend(
                &x, &labels, &folds, 1.0, 10, false, &mut Rng::new(anchor), GramBackend::Primal,
            )
            .unwrap();
            for backend in backends {
                let serial = analytic_binary_permutation_backend(
                    &x, &labels, &folds, 1.0, 10, false, &mut Rng::new(anchor), backend,
                )
                .unwrap();
                assert_eq!(serial.null, golden.null, "binary serial {backend:?} (P={p})");
                assert_eq!(serial.observed, golden.observed);
                let batched = analytic_binary_permutation_batched_backend(
                    &x,
                    &labels,
                    &folds,
                    1.0,
                    10,
                    false,
                    &mut Rng::new(anchor),
                    BatchStrategy::new(4, 2),
                    backend,
                )
                .unwrap();
                assert_eq!(batched.null, golden.null, "binary batched {backend:?} (P={p})");
            }
            // default entry points (now Auto) stay pinned to the Primal-built
            // golden — the flip changed the hat build's cost, not a bit of
            // any recorded null distribution.
            let default_serial = analytic_binary_permutation(
                &x, &labels, &folds, 1.0, 10, false, &mut Rng::new(anchor),
            )
            .unwrap();
            assert_eq!(
                default_serial.null, golden.null,
                "the Auto default must reproduce the recorded Primal golden (resolved {resolved:?})"
            );
            let default_batched = analytic_binary_permutation_batched(
                &x,
                &labels,
                &folds,
                1.0,
                10,
                false,
                &mut Rng::new(anchor),
                BatchStrategy::new(4, 2),
            )
            .unwrap();
            assert_eq!(default_batched.null, golden.null, "batched Auto default vs golden");
        }
        // Multi-class pair of engines, same discipline. The cross-backend
        // sweep runs on the wide shape only — on tall data `Auto` resolves
        // to `Primal`, so the flip never changed the tall path; there the
        // engines + defaults are pinned under `Primal` alone.
        for &(per, p, seed) in &[(7usize, 36usize, 403u64), (9, 5, 404)] {
            let mut rng = Rng::new(seed);
            let (x, labels) = blobs(&mut rng, per, 3, p, 2.5);
            let folds = stratified_kfold(&labels, 3, &mut rng);
            let wide = p > labels.len();
            assert_eq!(
                GramBackend::Auto.resolve(labels.len(), p, 1.0),
                if wide { GramBackend::Dual } else { GramBackend::Primal },
                "multi-class Auto resolution moved (N={}, P={p})",
                labels.len()
            );
            let anchor = 4321 + seed;
            let golden = analytic_multiclass_permutation_backend(
                &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(anchor), GramBackend::Primal,
            )
            .unwrap();
            let swept: &[GramBackend] =
                if wide { &backends } else { &[GramBackend::Primal] };
            for &backend in swept {
                let serial = analytic_multiclass_permutation_backend(
                    &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(anchor), backend,
                )
                .unwrap();
                assert_eq!(serial.null, golden.null, "multi serial {backend:?} (P={p})");
                let batched = analytic_multiclass_permutation_batched_backend(
                    &x,
                    &labels,
                    3,
                    &folds,
                    1.0,
                    6,
                    &mut Rng::new(anchor),
                    BatchStrategy::new(3, 2),
                    backend,
                )
                .unwrap();
                assert_eq!(batched.null, golden.null, "multi batched {backend:?} (P={p})");
            }
            let default_serial = analytic_multiclass_permutation(
                &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(anchor),
            )
            .unwrap();
            assert_eq!(
                default_serial.null, golden.null,
                "multi serial Auto default must reproduce the recorded Primal golden"
            );
            let default_batched = analytic_multiclass_permutation_batched(
                &x,
                &labels,
                3,
                &folds,
                1.0,
                6,
                &mut Rng::new(anchor),
                BatchStrategy::new(3, 2),
            )
            .unwrap();
            assert_eq!(default_batched.null, golden.null, "multi batched Auto default vs golden");
        }
    }

    #[test]
    fn backend_golden_null_distributions_pinned_under_forced_isa_dispatch() {
        // SIMD kernel dispatch (`linalg::dispatch`) must be invisible to
        // every recorded null: the vector microkernels pin the scalar
        // accumulation order bit-for-bit (vector lanes are *distinct*
        // output elements, multiply-then-add with no FMA contraction,
        // ascending index order), so running the full perm engines under a
        // forced SIMD ISA must reproduce the forced-scalar golden exactly.
        // This is the end-to-end leg of the kernel-conformance contract:
        // Gram builds, Cholesky factor/solves, hat applications, and the
        // batched pool path all under the overridden kernel table.
        //
        // `force_scope` holds a process-wide lock, so each engine run is
        // wrapped in a closure that acquires the guard, runs, and drops it
        // before the next ISA is forced.
        use crate::fastcv::perm::{
            analytic_binary_permutation_backend, analytic_multiclass_permutation_backend,
        };
        use crate::linalg::dispatch::{self, Isa};

        // One wide binary shape (Auto -> Dual: N×N Gram + dual hat) and one
        // tall multiclass shape (Auto -> Primal: P×P Gram + primal solves)
        // — together they route through every kernel family the dispatch
        // table overrides.
        let run_binary = |isa: Isa| {
            let mut rng = Rng::new(411);
            let (x, labels) = blobs(&mut rng, 8, 2, 40, 2.0);
            let folds = stratified_kfold(&labels, 4, &mut rng);
            // `isa` only ever comes from `Isa::supported()`, so the force
            // cannot bail.
            let _g = dispatch::force_scope(isa).unwrap();
            let serial = analytic_binary_permutation_backend(
                &x, &labels, &folds, 1.0, 10, false, &mut Rng::new(1645), GramBackend::Auto,
            )
            .unwrap();
            let batched = analytic_binary_permutation_batched_backend(
                &x,
                &labels,
                &folds,
                1.0,
                10,
                false,
                &mut Rng::new(1645),
                BatchStrategy::new(4, 2),
                GramBackend::Auto,
            )
            .unwrap();
            (serial, batched)
        };
        let run_multi = |isa: Isa| {
            let mut rng = Rng::new(412);
            let (x, labels) = blobs(&mut rng, 9, 3, 5, 2.5);
            let folds = stratified_kfold(&labels, 3, &mut rng);
            let _g = dispatch::force_scope(isa).unwrap();
            let serial = analytic_multiclass_permutation_backend(
                &x, &labels, 3, &folds, 1.0, 6, &mut Rng::new(4745), GramBackend::Auto,
            )
            .unwrap();
            let batched = analytic_multiclass_permutation_batched_backend(
                &x,
                &labels,
                3,
                &folds,
                1.0,
                6,
                &mut Rng::new(4745),
                BatchStrategy::new(3, 2),
                GramBackend::Auto,
            )
            .unwrap();
            (serial, batched)
        };

        let (bin_serial_gold, bin_batched_gold) = run_binary(Isa::Scalar);
        let (multi_serial_gold, multi_batched_gold) = run_multi(Isa::Scalar);
        // The batched engines already agree with serial under scalar — the
        // cross-ISA assertions below then pin all four corners at once.
        assert_eq!(bin_batched_gold.null, bin_serial_gold.null, "scalar batched vs serial");
        assert_eq!(multi_batched_gold.null, multi_serial_gold.null, "scalar multi batched");

        for isa in Isa::supported() {
            if isa == Isa::Scalar {
                continue;
            }
            let (serial, batched) = run_binary(isa);
            assert_eq!(serial.null, bin_serial_gold.null, "binary serial under forced {isa}");
            assert_eq!(
                serial.observed, bin_serial_gold.observed,
                "binary observed under forced {isa}"
            );
            assert_eq!(batched.null, bin_batched_gold.null, "binary batched under forced {isa}");
            let (serial, batched) = run_multi(isa);
            assert_eq!(serial.null, multi_serial_gold.null, "multi serial under forced {isa}");
            assert_eq!(
                serial.observed, multi_serial_gold.observed,
                "multi observed under forced {isa}"
            );
            assert_eq!(batched.null, multi_batched_gold.null, "multi batched under forced {isa}");
        }
    }
}
