//! Artifact registry: parse `artifacts/manifest.json` and resolve
//! (op, shape) requests to HLO files.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Identity of one compiled graph variant.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ArtifactKey {
    /// Operation name (`analytic_cv`, `analytic_cv_batch`, `hat_matrix`,
    /// `analytic_mc_step1`).
    pub op: String,
    /// Samples.
    pub n: usize,
    /// Features.
    pub p: usize,
    /// Folds (0 when not applicable).
    pub k_folds: usize,
    /// Permutation batch (0 when not applicable).
    pub batch: usize,
    /// Classes (0 when not applicable).
    pub c: usize,
}

impl ArtifactKey {
    /// Key for the single-response analytic CV graph.
    pub fn analytic_cv(n: usize, p: usize, k_folds: usize) -> ArtifactKey {
        ArtifactKey { op: "analytic_cv".into(), n, p, k_folds, batch: 0, c: 0 }
    }

    /// Key for the batched (permutation) analytic CV graph.
    pub fn analytic_cv_batch(n: usize, p: usize, k_folds: usize, batch: usize) -> ArtifactKey {
        ArtifactKey { op: "analytic_cv_batch".into(), n, p, k_folds, batch, c: 0 }
    }

    /// Key for the bare hat-matrix graph.
    pub fn hat_matrix(n: usize, p: usize) -> ArtifactKey {
        ArtifactKey { op: "hat_matrix".into(), n, p, k_folds: 0, batch: 0, c: 0 }
    }

    /// Key for the multi-class step-1 graph.
    pub fn mc_step1(n: usize, p: usize, c: usize, k_folds: usize) -> ArtifactKey {
        ArtifactKey { op: "analytic_mc_step1".into(), n, p, k_folds, batch: 0, c }
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub key: ArtifactKey,
    pub file: PathBuf,
    pub dtype: String,
}

/// Parsed manifest: key → file.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    entries: BTreeMap<ArtifactKey, ArtifactEntry>,
    dir: PathBuf,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.json`. A missing manifest yields an empty
    /// registry (native fallback everywhere) rather than an error.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = dir.join("manifest.json");
        if !manifest.exists() {
            return Ok(ArtifactRegistry { entries: BTreeMap::new(), dir: dir.to_path_buf() });
        }
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut entries = BTreeMap::new();
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing 'artifacts' array")?;
        let dtype_default =
            json.get("dtype").and_then(|d| d.as_str()).unwrap_or("f64").to_string();
        for a in arts {
            let op = a.get("op").and_then(|v| v.as_str()).context("entry missing op")?;
            let get = |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let key = ArtifactKey {
                op: op.to_string(),
                n: get("n"),
                p: get("p"),
                k_folds: get("k_folds"),
                batch: get("batch"),
                c: get("c"),
            };
            let file = a.get("file").and_then(|v| v.as_str()).context("entry missing file")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            entries.insert(
                key.clone(),
                ArtifactEntry {
                    key,
                    file: path,
                    dtype: a
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or(&dtype_default)
                        .to_string(),
                },
            );
        }
        Ok(ArtifactRegistry { entries, dir: dir.to_path_buf() })
    }

    /// Load from the conventional location (`$FASTCV_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<ArtifactRegistry> {
        let dir = std::env::var("FASTCV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// Exact-shape lookup.
    pub fn find(&self, key: &ArtifactKey) -> Option<&ArtifactEntry> {
        self.entries.get(key)
    }

    /// All known entries.
    pub fn entries(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.values()
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Artifact directory this registry was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_empty_registry() {
        let reg = ArtifactRegistry::load(Path::new("/nonexistent-dir-xyz")).unwrap();
        assert!(reg.is_empty());
        assert!(reg.find(&ArtifactKey::analytic_cv(10, 2, 5)).is_none());
    }

    #[test]
    fn parses_manifest_fixture() {
        let dir = std::env::temp_dir().join(format!("fastcv-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"dtype":"f64","artifacts":[
                {"op":"analytic_cv","file":"a.hlo.txt","n":40,"p":8,"k_folds":5}
            ]}"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.len(), 1);
        let hit = reg.find(&ArtifactKey::analytic_cv(40, 8, 5)).unwrap();
        assert_eq!(hit.dtype, "f64");
        assert!(reg.find(&ArtifactKey::analytic_cv(41, 8, 5)).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join(format!("fastcv-test2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"op":"hat_matrix","file":"ghost.hlo.txt","n":4,"p":2}]}"#,
        )
        .unwrap();
        assert!(ArtifactRegistry::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
