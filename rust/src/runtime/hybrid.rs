//! Hybrid dispatch: run analytic CV through an AOT artifact when an exact
//! shape match exists, otherwise through the native Rust engine.
//!
//! The artifact graphs assume **contiguous equal-sized folds** (fold k owns
//! rows `k·nte..(k+1)·nte`); this module owns the row-permutation dance that
//! maps an arbitrary fold partition onto that layout and maps decision
//! values back.

use super::artifacts::ArtifactKey;
use super::client::{Value, XlaRuntime};
use crate::fastcv::binary::AnalyticBinaryCv;
use crate::linalg::Mat;
use anyhow::{Context, Result};

/// Which engine actually ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// AOT artifact through PJRT.
    Xla,
    /// Native Rust implementation.
    Native,
}

/// Check whether a partition is "contiguous-foldable": all folds the same
/// size. (Any partition can be permuted into the contiguous layout then.)
pub fn equal_fold_sizes(folds: &[Vec<usize>]) -> Option<usize> {
    let nte = folds.first()?.len();
    folds.iter().all(|f| f.len() == nte).then_some(nte)
}

/// Row permutation mapping fold-k test rows to block k, i.e. `order[pos] =
/// original_index`.
pub fn fold_order(folds: &[Vec<usize>]) -> Vec<usize> {
    folds.iter().flat_map(|f| f.iter().copied()).collect()
}

/// Analytic binary CV with hybrid dispatch. Returns the decision values in
/// the *original* row order plus which engine ran.
pub fn analytic_cv(
    rt: Option<&XlaRuntime>,
    x: &Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    lambda: f64,
) -> Result<(Vec<f64>, Engine)> {
    crate::fastcv::validate_folds(folds, x.rows())?;
    if let (Some(rt), Some(_nte)) = (rt, equal_fold_sizes(folds)) {
        let covers_all: usize = folds.iter().map(|f| f.len()).sum();
        let key = ArtifactKey::analytic_cv(x.rows(), x.cols(), folds.len());
        if covers_all == x.rows() && rt.has(&key) {
            let order = fold_order(folds);
            let x_perm = x.take_rows(&order);
            let y_perm: Vec<f64> = order.iter().map(|&i| y[i]).collect();
            let out = rt
                .execute(&key, &[Value::Matrix(x_perm), Value::Vec1(y_perm), Value::Scalar(lambda)])
                .context("artifact execution failed")?;
            let Value::Vec1(dv_perm) = &out[0] else {
                anyhow::bail!("artifact returned unexpected output type")
            };
            let mut dvals = vec![f64::NAN; x.rows()];
            for (pos, &orig) in order.iter().enumerate() {
                dvals[orig] = dv_perm[pos];
            }
            return Ok((dvals, Engine::Xla));
        }
    }
    let cv = AnalyticBinaryCv::fit(x, y, lambda)?;
    Ok((cv.decision_values(folds)?, Engine::Native))
}

/// Batched permutation CV (Alg. 1) with hybrid dispatch: `y_batch` rows are
/// (permuted) responses; returns (B, N) decision values in original order.
pub fn analytic_cv_batch(
    rt: Option<&XlaRuntime>,
    x: &Mat,
    y_batch: &[Vec<f64>],
    folds: &[Vec<usize>],
    lambda: f64,
) -> Result<(Vec<Vec<f64>>, Engine)> {
    crate::fastcv::validate_folds(folds, x.rows())?;
    let b = y_batch.len();
    if let (Some(rt), Some(_)) = (rt, equal_fold_sizes(folds)) {
        let covers_all: usize = folds.iter().map(|f| f.len()).sum();
        let key = ArtifactKey::analytic_cv_batch(x.rows(), x.cols(), folds.len(), b);
        if covers_all == x.rows() && rt.has(&key) {
            let order = fold_order(folds);
            let x_perm = x.take_rows(&order);
            let mut yb = Mat::zeros(b, x.rows());
            for (r, y) in y_batch.iter().enumerate() {
                for (pos, &orig) in order.iter().enumerate() {
                    yb[(r, pos)] = y[orig];
                }
            }
            let out = rt
                .execute(&key, &[Value::Matrix(x_perm), Value::Matrix(yb), Value::Scalar(lambda)])
                .context("artifact execution failed")?;
            let Value::Matrix(dv) = &out[0] else {
                anyhow::bail!("artifact returned unexpected output type")
            };
            let mut result = vec![vec![f64::NAN; x.rows()]; b];
            for r in 0..b {
                for (pos, &orig) in order.iter().enumerate() {
                    result[r][orig] = dv[(r, pos)];
                }
            }
            return Ok((result, Engine::Xla));
        }
    }
    // Native: one hat matrix + fold cache, response swapped per batch row.
    let mut cv = AnalyticBinaryCv::fit(x, y_batch.first().context("empty batch")?, lambda)?;
    let cache = crate::fastcv::FoldCache::prepare(&cv.hat, folds, false)?;
    let mut result = Vec::with_capacity(b);
    for y in y_batch {
        cv.set_response(y);
        result.push(cv.decision_values_cached(&cache));
    }
    Ok((result, Engine::Native))
}

/// Multi-class analytic CV (Alg. 2) with hybrid dispatch: step 1 (the
/// expensive indicator-matrix regression + Eq. 14/15 fits) runs through the
/// `analytic_mc_step1` artifact when shapes match; step 2 (per-fold `C×C`
/// optimal-scores eig + nearest-centroid) always runs natively, mirroring
/// the paper's observation that step 2 is negligible.
pub fn analytic_multiclass_cv(
    rt: Option<&XlaRuntime>,
    x: &Mat,
    labels: &[usize],
    c: usize,
    folds: &[Vec<usize>],
    lambda: f64,
) -> Result<(Vec<usize>, Engine)> {
    crate::fastcv::validate_folds(folds, x.rows())?;
    let n = x.rows();
    if let (Some(rt), Some(nte)) = (rt, equal_fold_sizes(folds)) {
        let covers_all: usize = folds.iter().map(|f| f.len()).sum();
        let key = ArtifactKey::mc_step1(n, x.cols(), c, folds.len());
        if covers_all == n && rt.has(&key) {
            let order = fold_order(folds);
            let x_perm = x.take_rows(&order);
            let labels_perm: Vec<usize> = order.iter().map(|&i| labels[i]).collect();
            let y_ind = crate::model::optimal_scoring::indicator_matrix(&labels_perm, c);
            let out = rt
                .execute(&key, &[Value::Matrix(x_perm), Value::Matrix(y_ind.clone()), Value::Scalar(lambda)])
                .context("mc_step1 artifact failed")?;
            let Value::Matrix(y_dot) = &out[0] else {
                anyhow::bail!("mc_step1 output 0: expected (N,C) matrix")
            };
            let Value::Tensor3 { dims, data } = &out[1] else {
                anyhow::bail!("mc_step1 output 1: expected (K,N,C) tensor")
            };
            anyhow::ensure!(dims == &[folds.len(), n, c], "tensor dims {:?}", dims);
            // --- step 2 per fold, in permuted coordinates ---
            let mut pred_perm = vec![usize::MAX; n];
            for k in 0..folds.len() {
                let te: Vec<usize> = (k * nte..(k + 1) * nte).collect();
                let tr: Vec<usize> =
                    (0..n).filter(|i| !(k * nte..(k + 1) * nte).contains(i)).collect();
                let counts: Vec<f64> = {
                    let mut cnt = vec![0.0; c];
                    for &i in &tr {
                        cnt[labels_perm[i]] += 1.0;
                    }
                    cnt
                };
                anyhow::ensure!(
                    counts.iter().all(|&v| v > 0.0),
                    "fold {k}: class absent from training set"
                );
                let n_tr = tr.len();
                // Ẏ_Tr from the (K,N,C) tensor; Y_Tr from the indicator.
                let y_dot_tr = Mat::from_fn(n_tr, c, |j, l| {
                    data[k * n * c + tr[j] * c + l]
                });
                let y_tr = Mat::from_fn(n_tr, c, |j, l| y_ind[(tr[j], l)]);
                let mut m = crate::linalg::matmul(&y_dot_tr.t(), &y_tr);
                m.scale(1.0 / n_tr as f64);
                let dp = Mat::diag(
                    &counts.iter().map(|&v| v / n_tr as f64).collect::<Vec<_>>(),
                );
                let basis = crate::model::optimal_scoring::score_basis(&m, &dp, n_tr)?;
                let theta_d = Mat::from_fn(c, basis.theta.cols(), |i, j| {
                    basis.theta[(i, j)] * basis.d[j]
                });
                let y_dot_te = Mat::from_fn(nte, c, |j, l| y_dot[(te[j], l)]);
                let z_te = crate::linalg::matmul(&y_dot_te, &theta_d);
                let z_tr = crate::linalg::matmul(&y_dot_tr, &theta_d);
                let mut centroids = Mat::zeros(c, z_tr.cols());
                for (j, &i) in tr.iter().enumerate() {
                    let l = labels_perm[i];
                    for q in 0..z_tr.cols() {
                        centroids[(l, q)] += z_tr[(j, q)];
                    }
                }
                for l in 0..c {
                    let inv = 1.0 / counts[l];
                    for q in 0..z_tr.cols() {
                        centroids[(l, q)] *= inv;
                    }
                }
                let fold_pred =
                    crate::model::lda_multiclass::nearest_centroid(&z_te, &centroids);
                for (j, &i) in te.iter().enumerate() {
                    pred_perm[i] = fold_pred[j];
                }
            }
            // un-permute
            let mut pred = vec![usize::MAX; n];
            for (pos, &orig) in order.iter().enumerate() {
                pred[orig] = pred_perm[pos];
            }
            return Ok((pred, Engine::Xla));
        }
    }
    let cv = crate::fastcv::multiclass::AnalyticMulticlassCv::fit(x, labels, c, lambda)?;
    Ok((cv.predict(folds)?, Engine::Native))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::folds::kfold;
    use crate::util::rng::Rng;

    #[test]
    fn fold_order_roundtrip() {
        let folds = vec![vec![3, 5], vec![0, 4], vec![1, 2]];
        let order = fold_order(&folds);
        assert_eq!(order, vec![3, 5, 0, 4, 1, 2]);
        assert_eq!(equal_fold_sizes(&folds), Some(2));
        let ragged = vec![vec![0], vec![1, 2]];
        assert_eq!(equal_fold_sizes(&ragged), None);
    }

    #[test]
    fn native_fallback_works_without_runtime() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(30, 4, |_, _| rng.gauss());
        let y: Vec<f64> = (0..30).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let folds = kfold(30, 5, &mut rng);
        let (dv, engine) = analytic_cv(None, &x, &y, &folds, 0.2).unwrap();
        assert_eq!(engine, Engine::Native);
        assert!(dv.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn xla_and_native_agree_when_artifact_present() {
        let Ok(rt) = XlaRuntime::load_default() else { return };
        if rt.registry().is_empty() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let key = ArtifactKey::analytic_cv(40, 8, 5);
        if !rt.has(&key) {
            return;
        }
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(40, 8, |_, _| rng.gauss());
        let y: Vec<f64> = (0..40).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let folds = kfold(40, 5, &mut rng); // random partition, equal sizes
        let (dv_xla, e1) = analytic_cv(Some(&rt), &x, &y, &folds, 0.7).unwrap();
        assert_eq!(e1, Engine::Xla);
        let (dv_nat, e2) = analytic_cv(None, &x, &y, &folds, 0.7).unwrap();
        assert_eq!(e2, Engine::Native);
        crate::util::prop::assert_all_close(&dv_xla, &dv_nat, 1e-9, "hybrid parity");
    }

    #[test]
    fn multiclass_hybrid_parity() {
        let Ok(rt) = XlaRuntime::load_default() else { return };
        let key = ArtifactKey::mc_step1(60, 12, 3, 5);
        if !rt.has(&key) {
            eprintln!("skipping: mc_step1 artifact absent");
            return;
        }
        let mut rng = Rng::new(21);
        let ds = crate::data::synthetic::generate(
            &crate::data::synthetic::SyntheticSpec::multiclass(60, 12, 3),
            &mut rng,
        );
        let folds = kfold(60, 5, &mut rng);
        let (pred_xla, e1) =
            analytic_multiclass_cv(Some(&rt), &ds.x, &ds.labels, 3, &folds, 0.6).unwrap();
        assert_eq!(e1, Engine::Xla);
        let (pred_nat, e2) =
            analytic_multiclass_cv(None, &ds.x, &ds.labels, 3, &folds, 0.6).unwrap();
        assert_eq!(e2, Engine::Native);
        assert_eq!(pred_xla, pred_nat, "multiclass hybrid parity");
        // and against retraining
        let std =
            crate::fastcv::multiclass::standard_cv_predict(&ds.x, &ds.labels, 3, &folds, 0.6)
                .unwrap();
        assert_eq!(pred_xla, std);
    }

    #[test]
    fn batch_native_matches_single_calls() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(20, 3, |_, _| rng.gauss());
        let y: Vec<f64> = (0..20).map(|i| if i < 10 { 1.0 } else { -1.0 }).collect();
        let folds = kfold(20, 4, &mut rng);
        let mut perms = Vec::new();
        for _ in 0..3 {
            let p = rng.permutation(20);
            perms.push(p.iter().map(|&i| y[i]).collect::<Vec<f64>>());
        }
        let (batch, _) = analytic_cv_batch(None, &x, &perms, &folds, 0.4).unwrap();
        for (row, yp) in batch.iter().zip(&perms) {
            let (single, _) = analytic_cv(None, &x, yp, &folds, 0.4).unwrap();
            crate::util::prop::assert_all_close(row, &single, 1e-10, "batch vs single");
        }
    }
}
