//! A fault-tolerant client for the `fastcv serve` Unix-socket daemon:
//! deterministic capped-exponential backoff, reconnect-on-drop, and
//! retry of **idempotent** ops only.
//!
//! The retry policy is driven by the server's typed error taxonomy
//! ([`crate::error::FastCvError`]): a response whose `"kind"` maps to a
//! retryable error (`overloaded`, `deadline_exceeded`, `worker_panic`) is
//! retried with backoff; `bad_request` and `corrupt` are returned as-is
//! because the same bytes would fail the same way again. Transport
//! failures (connect refused, connection dropped mid-exchange) are always
//! retryable — but only for idempotent ops (`search`, `perm`, `sweep`,
//! `stats`). `shutdown` is never retried: after a drop the client cannot
//! know whether the daemon already acted on it.
//!
//! Backoff delays are a pure function of the attempt index — no clock, no
//! jitter — so a chaos run with a pinned [`crate::fastcv::fault`] plan
//! replays bit-for-bit (docs/ROBUSTNESS.md).

use crate::error::FastCvError;
use crate::fastcv::fault;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Deterministic capped-exponential backoff: attempt `i` sleeps
/// `min(cap_ms, base_ms << i)` milliseconds. No jitter — retries must
/// replay identically under a pinned fault plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Backoff {
    /// Delay before the first retry, in milliseconds.
    pub base_ms: u64,
    /// Ceiling on any single delay, in milliseconds.
    pub cap_ms: u64,
    /// Retries after the initial attempt (so `max_retries + 1` attempts
    /// total). `0` disables retrying entirely.
    pub max_retries: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff { base_ms: 10, cap_ms: 2_000, max_retries: 4 }
    }
}

impl Backoff {
    /// The delay before retry number `attempt` (0-based), in milliseconds.
    pub fn delay_ms(&self, attempt: u32) -> u64 {
        // The shift saturates at 2^20 · base, far past any sane cap, so
        // the min() below governs; min(20) keeps the shift itself defined.
        self.cap_ms.min(self.base_ms.saturating_mul(1u64 << attempt.min(20)))
    }
}

/// Ops the daemon evaluates as pure functions of the request — safe to
/// resend after an ambiguous failure. `shutdown` is excluded: resending
/// it after a drop could stop a daemon the first send already stopped
/// (or a freshly restarted one).
fn idempotent(op: &str) -> bool {
    matches!(op, "search" | "perm" | "sweep" | "stats")
}

/// A line-oriented NDJSON client for `fastcv serve --socket`, with
/// reconnect and deterministic retry (see the module docs for policy).
pub struct ServeClient {
    path: PathBuf,
    backoff: Backoff,
    conn: Option<BufReader<UnixStream>>,
    retries: u64,
}

impl ServeClient {
    /// A client for the daemon listening at `path`, with the default
    /// backoff. No connection is made until the first [`call`](Self::call).
    pub fn new(path: &Path) -> Self {
        Self::with_backoff(path, Backoff::default())
    }

    /// A client with an explicit retry policy.
    pub fn with_backoff(path: &Path, backoff: Backoff) -> Self {
        ServeClient { path: path.to_path_buf(), backoff, conn: None, retries: 0 }
    }

    /// How many retries (reconnect-and-resend cycles) this client has
    /// performed over its lifetime.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Send `request` and return the daemon's response line, parsed.
    ///
    /// Idempotent ops retry transport failures and retryable error kinds
    /// up to `backoff.max_retries` times; the final outcome — including a
    /// still-failing typed response — is returned rather than masked, so
    /// callers always see the daemon's own words.
    pub fn call(&mut self, request: &Json) -> Result<Json> {
        let op = request.get("op").and_then(Json::as_str).unwrap_or("");
        let budget = if idempotent(op) { self.backoff.max_retries } else { 0 };
        let line = request.dump();
        let mut attempt = 0u32;
        loop {
            match self.exchange(&line) {
                Ok(resp) => {
                    let retryable = resp
                        .get("kind")
                        .and_then(Json::as_str)
                        .and_then(FastCvError::from_kind)
                        .is_some_and(|e| e.is_retryable());
                    if !retryable || attempt >= budget {
                        return Ok(resp);
                    }
                }
                Err(e) => {
                    // Transport error: the connection is unusable either
                    // way; drop it so the next attempt reconnects.
                    self.conn = None;
                    if attempt >= budget {
                        return Err(e.context(format!(
                            "serve call failed after {attempt} retries"
                        )));
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(self.backoff.delay_ms(attempt)));
            attempt += 1;
            self.retries += 1;
        }
    }

    /// One send/receive round trip on the (lazily opened) connection.
    fn exchange(&mut self, line: &str) -> Result<Json> {
        if self.conn.is_none() {
            let stream = UnixStream::connect(&self.path)
                .with_context(|| format!("connect to serve socket {:?}", self.path))?;
            self.conn = Some(BufReader::new(stream));
        }
        // Chaos hook: a planned `client.conn.drop` arrival severs the
        // connection right before the send — the ambiguous-failure case
        // the retry policy exists for.
        if fault::hit("client.conn.drop").is_some() {
            self.conn = None;
            return Err(anyhow!("injected fault: client connection dropped"));
        }
        let Some(conn) = self.conn.as_mut() else {
            return Err(anyhow!("connection vanished before the send"));
        };
        let sock = conn.get_mut();
        sock.write_all(line.as_bytes()).context("send request line")?;
        sock.write_all(b"\n").context("send request newline")?;
        sock.flush().context("flush request")?;
        let mut resp = String::new();
        let n = conn.read_line(&mut resp).context("read response line")?;
        if n == 0 {
            return Err(anyhow!("daemon closed the connection before answering"));
        }
        Json::parse(resp.trim_end())
            .map_err(|e| anyhow!("daemon sent an unparseable response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastcv::fault::{install, FaultPlan};
    use crate::serve::{ServeConfig, Server};

    #[test]
    fn backoff_is_deterministic_and_capped() {
        let b = Backoff { base_ms: 10, cap_ms: 100, max_retries: 8 };
        assert_eq!(b.delay_ms(0), 10);
        assert_eq!(b.delay_ms(1), 20);
        assert_eq!(b.delay_ms(2), 40);
        assert_eq!(b.delay_ms(3), 80);
        assert_eq!(b.delay_ms(4), 100, "capped");
        assert_eq!(b.delay_ms(63), 100, "huge attempts saturate, not overflow");
        assert!(idempotent("stats") && idempotent("perm"));
        assert!(!idempotent("shutdown") && !idempotent(""));
    }

    #[test]
    fn chaos_client_retries_a_dropped_connection_and_succeeds() {
        let _scope = install(FaultPlan::parse("client.conn.drop@1").unwrap());
        let dir = std::env::temp_dir()
            .join(format!("fastcv_serve_client_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("c.sock");
        let server = Server::new(ServeConfig::default());
        std::thread::scope(|scope| {
            let daemon = scope.spawn(|| server.serve_unix(&sock));
            let backoff = Backoff { base_ms: 1, cap_ms: 5, max_retries: 3 };
            let mut client = ServeClient::with_backoff(&sock, backoff);
            // Wait for the socket, then call: the first send is severed by
            // the injected drop, the retry reconnects and gets an answer.
            let mut last = None;
            for _ in 0..500 {
                match client.call(&Json::parse(r#"{"id":1,"op":"stats"}"#).unwrap()) {
                    Ok(resp) => {
                        last = Some(resp);
                        break;
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            }
            let resp = last.expect("daemon never answered");
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.dump());
            assert!(client.retries() >= 1, "the drop must have cost a retry");
            // shutdown is not retried; a single clean call stops the daemon.
            let resp = client
                .call(&Json::parse(r#"{"id":2,"op":"shutdown"}"#).unwrap())
                .unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            daemon.join().unwrap().unwrap();
        });
        std::fs::remove_dir_all(&dir).ok();
    }
}
