//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 graphs to HLO
//! text under `artifacts/` plus a `manifest.json`. This module is the only
//! place the `xla` crate is touched: a CPU PJRT client compiles each HLO
//! module once (cached per artifact) and executes it with `f64` literals
//! marshalled from [`crate::linalg::Mat`].
//!
//! Because HLO artifacts are shape-static while the paper's sweeps vary
//! (N, P, K) freely, [`hybrid`] dispatches to an exact-shape artifact when
//! one exists and to the native Rust engine otherwise — with tests pinning
//! both paths to identical numerics.

pub mod artifacts;
pub mod client;
pub mod hybrid;
pub mod serve_client;

pub use artifacts::{ArtifactKey, ArtifactRegistry};
pub use client::XlaRuntime;
pub use serve_client::{Backoff, ServeClient};
