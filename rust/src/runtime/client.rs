//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute many
//! times. Adapted from /opt/xla-example/load_hlo (the smoke-verified
//! reference wiring for this image).

use super::artifacts::{ArtifactEntry, ArtifactKey, ArtifactRegistry};
use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// A lazily-compiling XLA runtime over the artifact registry.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    // Executable cache keyed by artifact key. PjRtLoadedExecutable is not
    // Sync-guaranteed by the crate, so the whole cache sits behind a Mutex.
    cache: Mutex<BTreeMap<ArtifactKey, xla::PjRtLoadedExecutable>>,
}

/// A host-side value passed to / returned from an artifact call.
#[derive(Clone, Debug)]
pub enum Value {
    /// Scalar f64.
    Scalar(f64),
    /// 1-D vector.
    Vec1(Vec<f64>),
    /// Row-major matrix.
    Matrix(Mat),
    /// Rank-3 tensor (e.g. the (K, N, C) training-fit stack), row-major.
    Tensor3 { dims: [usize; 3], data: Vec<f64> },
}

impl Value {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::Scalar(x) => xla::Literal::from(*x),
            Value::Vec1(v) => xla::Literal::vec1(v),
            Value::Matrix(m) => xla::Literal::vec1(m.as_slice())
                .reshape(&[m.rows() as i64, m.cols() as i64])?,
            Value::Tensor3 { dims, data } => xla::Literal::vec1(data)
                .reshape(&[dims[0] as i64, dims[1] as i64, dims[2] as i64])?,
        })
    }

    /// Interpret a literal of known element type f64.
    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f64>()?;
        Ok(match dims.len() {
            0 => Value::Scalar(data[0]),
            1 => Value::Vec1(data),
            2 => Value::Matrix(Mat::from_vec(dims[0], dims[1], data)),
            3 => Value::Tensor3 { dims: [dims[0], dims[1], dims[2]], data },
            r => anyhow::bail!("unsupported output rank {r}"),
        })
    }
}

impl XlaRuntime {
    /// Create a CPU PJRT client over a registry.
    pub fn new(registry: ArtifactRegistry) -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, registry, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Create from the default artifact location.
    pub fn load_default() -> Result<XlaRuntime> {
        Self::new(ArtifactRegistry::load_default()?)
    }

    /// The registry backing this runtime.
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// PJRT platform string (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Is an exact-shape artifact available?
    pub fn has(&self, key: &ArtifactKey) -> bool {
        self.registry.find(key).is_some()
    }

    fn compile_entry(&self, entry: &ArtifactEntry) -> Result<xla::PjRtLoadedExecutable> {
        let path = entry
            .file
            .to_str()
            .context("artifact path not valid UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.file.display()))
    }

    /// Execute an artifact with the given inputs. Outputs are the elements
    /// of the result tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, key: &ArtifactKey, inputs: &[Value]) -> Result<Vec<Value>> {
        let entry = self
            .registry
            .find(key)
            .with_context(|| format!("no artifact for {key:?}"))?
            .clone();
        // lint:allow(panic, reason = "mutex poisoning requires a panic while holding the cache lock; compile/insert below propagate errors instead of panicking")
        let mut cache = self.cache.lock().unwrap();
        let exe = match cache.entry(key.clone()) {
            std::collections::btree_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => v.insert(self.compile_entry(&entry)?),
        };
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .context("executing artifact")?[0][0]
            .to_literal_sync()?;
        // return_tuple=True → unpack the tuple elements.
        let tuple = result.to_tuple()?;
        anyhow::ensure!(!tuple.is_empty(), "empty result tuple");
        tuple.iter().map(Value::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are skipped
    /// (with a note) when the registry is empty so `cargo test` stays
    /// green in a fresh checkout.
    fn runtime() -> Option<XlaRuntime> {
        let rt = XlaRuntime::load_default().ok()?;
        if rt.registry().is_empty() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(rt)
    }

    #[test]
    fn hat_artifact_matches_native() {
        let Some(rt) = runtime() else { return };
        let key = ArtifactKey::hat_matrix(40, 8);
        if !rt.has(&key) {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(42);
        let x = Mat::from_fn(40, 8, |_, _| rng.gauss());
        let lambda = 0.5;
        let out = rt
            .execute(&key, &[Value::Matrix(x.clone()), Value::Scalar(lambda)])
            .unwrap();
        let Value::Matrix(h_xla) = &out[0] else { panic!("expected matrix") };
        let h_native = crate::fastcv::hat::HatMatrix::build(&x, lambda).unwrap();
        assert!(
            h_xla.max_abs_diff(&h_native.h) < 1e-9,
            "XLA vs native hat matrix: {}",
            h_xla.max_abs_diff(&h_native.h)
        );
    }

    #[test]
    fn analytic_cv_artifact_matches_native() {
        let Some(rt) = runtime() else { return };
        let key = ArtifactKey::analytic_cv(40, 8, 5);
        if !rt.has(&key) {
            return;
        }
        let mut rng = crate::util::rng::Rng::new(7);
        let x = Mat::from_fn(40, 8, |_, _| rng.gauss());
        let y: Vec<f64> = (0..40).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let lambda = 0.3;
        let out = rt
            .execute(
                &key,
                &[Value::Matrix(x.clone()), Value::Vec1(y.clone()), Value::Scalar(lambda)],
            )
            .unwrap();
        let Value::Vec1(dv_xla) = &out[0] else { panic!("expected vec") };
        // native with contiguous folds 8×5
        let folds: Vec<Vec<usize>> = (0..5).map(|k| (k * 8..(k + 1) * 8).collect()).collect();
        let cv = crate::fastcv::binary::AnalyticBinaryCv::fit(&x, &y, lambda).unwrap();
        let dv_native = cv.decision_values(&folds).unwrap();
        crate::util::prop::assert_all_close(dv_xla, &dv_native, 1e-9, "XLA vs native CV");
    }
}
