//! Panic containment for the serve daemon: the `catch_unwind` boundary
//! that keeps a panicking request from killing the process, plus the
//! injection hook the chaos suite uses to *cause* such panics on demand.
//!
//! This file is on the lint L4 allowlist (`PANIC_ALLOWED_FILES`): the
//! `panic!` here is the deliberate fault-injection path for the
//! `serve.worker.panic` / `serve.queue.panic` sites, and the boundary
//! itself exists so that panics — injected or real — become typed
//! `worker_panic` responses instead of dead daemons (docs/ROBUSTNESS.md).
//!
//! Why `AssertUnwindSafe` is sound here: everything the worker closures
//! share (`Server`, `Queue`, the response writer) sits behind atomics or
//! mutexes, and every lock in the serve layer recovers from poisoning via
//! `PoisonError::into_inner` — a panic mid-critical-section leaves data
//! that the daemon's own invariants (first-insert-wins store, per-request
//! response encoding) tolerate. The chaos suite pins exactly this:
//! a poisoned jobs mutex and the requests after it still get served.

use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `f`, converting a panic into `Err(message)` instead of unwinding
/// into the worker scope (where it would abort the daemon's thread join).
pub(crate) fn run_caught<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => Err(panic_message(payload.as_ref())),
    }
}

/// Panic iff the fault plan says this arrival at `site` should fail —
/// the serve layer's `serve.worker.panic` / `serve.queue.panic` hooks.
/// A no-op (one atomic-ish map probe) when no plan lists the site.
pub(crate) fn maybe_panic(site: &str) {
    if crate::fastcv::fault::hit(site).is_some() {
        panic!("injected fault: panic at {site}");
    }
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` payloads cover `panic!`/`assert!`/`unwrap` in practice).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_caught_passes_values_and_captures_panics() {
        assert_eq!(run_caught(|| 41 + 1), Ok(42));
        let err = run_caught(|| -> u32 { panic!("boom {}", 7) }).unwrap_err();
        assert!(err.contains("boom 7"), "{err}");
        let err = run_caught(|| {
            let s: Option<u32> = None;
            s.expect("empty option")
        })
        .unwrap_err();
        assert!(err.contains("empty option"), "{err}");
    }

    #[test]
    fn chaos_maybe_panic_fires_only_per_plan() {
        use crate::fastcv::fault::{install, FaultPlan};
        // No plan: silent.
        maybe_panic("serve.worker.panic.unlisted");
        let _scope = install(FaultPlan::parse("serve.worker.panic@2").unwrap());
        maybe_panic("serve.worker.panic"); // arrival 1: no trigger
        let err = run_caught(|| maybe_panic("serve.worker.panic")).unwrap_err();
        assert!(err.contains("serve.worker.panic"), "{err}");
        maybe_panic("serve.worker.panic"); // arrival 3: no trigger
    }
}
