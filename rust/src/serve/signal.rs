//! SIGTERM cleanup for `fastcv serve --socket`: unlink the socket file,
//! then exit — so a supervisor's kill never strands a stale socket that
//! would shadow the next daemon start.
//!
//! No `libc` crate exists in the offline build, so the three POSIX calls
//! are declared here directly. The handler body is restricted to
//! async-signal-safe functions (`unlink(2)`, `_exit(2)`) — no allocation,
//! no locks, no formatting — per signal-safety(7). This file is on the
//! lint L3 audited list (`UNSAFE_AUDITED_FILES`); every `unsafe` block
//! carries its justification in situ.
//!
//! The kill-and-restart smoke in `scripts/serve_smoke.sh` drives this
//! end to end: SIGTERM mid-serve → socket file gone → a restart on the
//! same spill directory comes up clean.

use anyhow::{Context, Result};
use std::ffi::CString;
use std::os::raw::{c_char, c_int};
use std::path::Path;
use std::sync::atomic::{AtomicPtr, Ordering};

extern "C" {
    fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    fn unlink(path: *const c_char) -> c_int;
    fn _exit(status: c_int) -> !;
}

const SIGTERM: c_int = 15;
/// `signal(2)` returns `SIG_ERR` (`(void*)-1`) on failure.
const SIG_ERR: usize = usize::MAX;

/// The socket path the handler unlinks, as a NUL-terminated C string
/// leaked into a raw pointer (the handler may fire at any instant for the
/// rest of the process lifetime, so the buffer must never be freed —
/// see [`install_sigterm_cleanup`]).
static SOCKET_PATH: AtomicPtr<c_char> = AtomicPtr::new(std::ptr::null_mut());

extern "C" fn on_sigterm(_sig: c_int) {
    let path = SOCKET_PATH.load(Ordering::SeqCst);
    // SAFETY: `path` is either null (checked) or a pointer produced by
    // `CString::into_raw` and intentionally never freed, so it is a valid
    // NUL-terminated string for the whole process lifetime. `unlink` and
    // `_exit` are both async-signal-safe (signal-safety(7)); nothing here
    // allocates, locks, or returns into interrupted code after `_exit`.
    unsafe {
        if !path.is_null() {
            unlink(path);
        }
        _exit(0);
    }
}

/// Install a `SIGTERM` handler that unlinks `path` (the serve socket) and
/// exits with status 0. Idempotent: a second call swaps in the new path;
/// the previous path buffer is deliberately leaked because a concurrently
/// delivered signal may still be reading it.
pub fn install_sigterm_cleanup(path: &Path) -> Result<()> {
    use std::os::unix::ffi::OsStrExt;
    let cpath = CString::new(path.as_os_str().as_bytes())
        .context("socket path contains a NUL byte")?;
    // Leaked on purpose: the handler owns a reference forever (see above).
    SOCKET_PATH.swap(cpath.into_raw(), Ordering::SeqCst);
    // SAFETY: installing a plain `extern "C" fn(c_int)` handler via
    // `signal(2)` with a valid signal number; the handler (above) is
    // async-signal-safe. The returned previous handler is only compared
    // against SIG_ERR, never called.
    let prev = unsafe { signal(SIGTERM, on_sigterm) };
    anyhow::ensure!(prev != SIG_ERR, "signal(SIGTERM) failed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_accepts_paths_and_rejects_interior_nul() {
        // Actually delivering SIGTERM would terminate the test binary; the
        // end-to-end path is exercised by scripts/serve_smoke.sh. Here:
        // installation succeeds, re-installation succeeds (path swap), and
        // a NUL-bearing path is a typed error, not a crash.
        install_sigterm_cleanup(Path::new("/tmp/fastcv_test.sock")).unwrap();
        install_sigterm_cleanup(Path::new("/tmp/fastcv_test2.sock")).unwrap();
        assert!(!SOCKET_PATH.load(Ordering::SeqCst).is_null());
        let bad = std::ffi::OsStr::new("a\0b");
        assert!(install_sigterm_cleanup(Path::new(bad)).is_err());
    }
}
