//! # `fastcv serve` — a threaded job queue over a shared [`FactorStore`]
//!
//! The sweep CLI amortises factor builds *within* one process invocation;
//! this module amortises them *across* requests: a long-lived daemon owns
//! one [`FactorStore`] and a pool of request workers, so every search /
//! permutation / sweep request that lands on the same dataset key reuses
//! the factors earlier requests paid for. Protocol, keying, eviction, and
//! coalescing semantics are documented in `docs/SERVE.md`.
//!
//! ## Protocol
//!
//! Newline-delimited JSON (NDJSON): one request object per line on stdin
//! (or a Unix socket via `--socket`), one response object per line out.
//! Every response carries the request's `id` (echoed verbatim), `"ok"`,
//! and a `"cache"` counter tag ([`StoreStats::tag`]). With more than one
//! worker, response *order* is not guaranteed — match responses to
//! requests by `id`.
//!
//! Ops: `search` (λ grid through
//! [`search_lambda_ctx`](crate::fastcv::lambda_search::search_lambda_ctx)),
//! `perm` (binary/multi-class permutation test), `sweep` (a Fig. 3 grid
//! through the coordinator's [`Scheduler`] sharing this server's store),
//! `stats` (store counters), `shutdown`.
//!
//! ## Coalescing
//!
//! Queued `perm` requests with an equal coalesce key — synthetic dataset
//! spec × fold spec × λ bits × bias × backend policy × tile tag — are
//! drained together and run as **one** pass of the jobs engine
//! ([`analytic_binary_permutation_jobs_ctx`]): one hat build, one fold
//! prep, one GEMM stream spanning every request's permutation columns.
//! Each request keeps its own determinism anchor
//! (`Rng::new(seed).next_u64()`), so its null distribution is
//! **bit-identical** to a standalone run with that seed (the jobs-engine
//! property tests). Requests with inline (non-synthetic) data are never
//! coalesced — fingerprinting them for a merge key would cost more than
//! the merge saves on typical inline payloads.
//!
//! ## Determinism
//!
//! No wall time or OS entropy feeds any result: datasets come from seeded
//! [`Rng`] streams, folds from a seeded fold RNG, permutation anchors from
//! request seeds. The store is a pure wall-clock/memory knob (its bitwise
//! contract), so a warm cache serves byte-identical results to a cold one.
//!
//! ## Robustness
//!
//! The daemon degrades, it does not die (docs/ROBUSTNESS.md): malformed
//! requests answer a typed `bad_request` naming the offending field and
//! leave the connection open; worker panics are caught at the
//! [`recover`] boundary and answer `worker_panic`; requests older than
//! `--deadline-ms` answer `deadline_exceeded` instead of running; a full
//! job queue (`--queue-cap`) rejects at admission with `overloaded`.
//! Every typed kind rides in the response's `"kind"` field
//! ([`crate::error::FastCvError`]), and the chaos fault sites
//! (`serve.worker.panic`, `serve.queue.panic`, `serve.conn.drop` — see
//! [`crate::fastcv::fault`]) let the `chaos_*` suites force each path
//! deterministically.

pub(crate) mod recover;
pub mod signal;

use crate::coordinator::sweep::{grid, Experiment, PermEngine, SweepScale};
use crate::coordinator::{Scheduler, SweepReport};
use crate::cv::folds::{kfold, stratified_kfold};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::data::Dataset;
use crate::error::FastCvError;
use crate::fastcv::fault;
use crate::fastcv::hat::GramBackend;
use crate::fastcv::lambda_search::{
    search_lambda_ctx, search_lambda_multiclass, SelectBy,
};
use crate::fastcv::perm_batch::{
    analytic_binary_permutation_jobs_ctx, analytic_multiclass_permutation_jobs_ctx,
    BatchStrategy, PermJob,
};
use crate::fastcv::ComputeContext;
use crate::linalg::{Mat, TilePolicy};
use crate::model::lda_binary::signed_codes;
use crate::store::{FactorStore, StoreStats};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, PoisonError};

/// Server configuration — the CLI's `fastcv serve` flags.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Request worker threads draining the queue (floored at 1). One
    /// worker preserves response order; more trade order for throughput.
    pub workers: usize,
    /// [`ComputeContext`] pool width per request (hat builds, fold prep,
    /// permutation batches). Wall-clock only — never moves a result.
    pub threads: usize,
    /// [`FactorStore`] resident-byte budget (`None` = unbounded).
    pub budget_bytes: Option<usize>,
    /// Spill directory for LRU demotion (and for the tile policy's
    /// out-of-core mode when `tile` is `Spill`).
    pub spill_dir: Option<PathBuf>,
    /// [`TilePolicy`] applied to every request's factor builds.
    pub tile: TilePolicy,
    /// Per-request deadline in milliseconds, measured from stream
    /// admission to worker dequeue (`0` = no deadline). A request that
    /// waited longer answers a typed `deadline_exceeded` instead of
    /// running — stale work is dropped before it wastes a factor build.
    pub deadline_ms: u64,
    /// Job-queue admission bound (`0` = unbounded). With the queue at
    /// capacity, new requests are rejected at admission with a typed
    /// `overloaded` response (`shutdown` is always admitted).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            threads: 1,
            budget_bytes: None,
            spill_dir: None,
            tile: TilePolicy::Off,
            deadline_ms: 0,
            queue_cap: 0,
        }
    }
}

/// The daemon: one [`FactorStore`] shared by every request, a coalescing
/// request queue, and the op handlers. Construct with [`Server::new`],
/// then drive it with [`Server::serve_stream`] (stdin/stdout or a socket
/// connection) or [`Server::process_batch`] (in-process: tests, benches).
pub struct Server {
    config: ServeConfig,
    store: FactorStore,
    /// Requests that rode along in another request's engine pass.
    coalesced: AtomicU64,
    /// Monotonic clock for deadline accounting — injected so tests drive
    /// expiry deterministically ([`Server::with_clock`]); never feeds a
    /// numeric result (the lint L2 discipline).
    clock: Box<dyn Fn() -> f64 + Send + Sync>,
    /// Worker panics caught at the [`recover`] boundary.
    panics: AtomicU64,
    /// Requests answered `deadline_exceeded` instead of running.
    deadline_misses: AtomicU64,
    /// Requests rejected `overloaded` at queue admission.
    rejected: AtomicU64,
}

/// Parsed request envelope: the echoed `id`, the op, and the raw body for
/// op-specific fields.
struct Request {
    id: Json,
    op: String,
    body: Json,
    /// Clock reading at stream admission (`None` off the queue path —
    /// `process_batch` runs synchronously, so deadlines don't apply).
    arrival: Option<f64>,
}

/// A typed `bad_request` naming the offending field, as `anyhow::Error`
/// (recovered by downcast at the response encoder).
fn bad(field: &str, detail: impl Into<String>) -> anyhow::Error {
    FastCvError::BadRequest { field: field.to_string(), detail: detail.into() }.into()
}

/// `body.get(field)` as a non-negative integer: absent → `default`,
/// present-but-mistyped → typed `bad_request` echoing `name`.
fn field_usize(body: &Json, field: &str, name: &str, default: usize) -> Result<usize> {
    match body.get(field) {
        None => Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| bad(name, format!("expected a non-negative integer, got {}", v.dump()))),
    }
}

/// `body.get(field)` as a finite number: absent → `default`,
/// present-but-mistyped (or NaN/infinite) → typed `bad_request`.
fn field_f64(body: &Json, field: &str, name: &str, default: f64) -> Result<f64> {
    match body.get(field) {
        None => Ok(default),
        Some(v) => match v.as_f64() {
            Some(x) if x.is_finite() => Ok(x),
            _ => Err(bad(name, format!("expected a finite number, got {}", v.dump()))),
        },
    }
}

/// `body.get(field)` as a string: absent → `default`.
fn field_str<'b>(body: &'b Json, field: &str, name: &str, default: &'b str) -> Result<&'b str> {
    match body.get(field) {
        None => Ok(default),
        Some(Json::Str(s)) => Ok(s),
        Some(v) => Err(bad(name, format!("expected a string, got {}", v.dump()))),
    }
}

/// `body.get(field)` as a bool: absent → `false`.
fn field_bool(body: &Json, field: &str, name: &str) -> Result<bool> {
    match body.get(field) {
        None => Ok(false),
        Some(Json::Bool(b)) => Ok(*b),
        Some(v) => Err(bad(name, format!("expected a boolean, got {}", v.dump()))),
    }
}

impl Request {
    fn parse(line: &str) -> Result<Request> {
        let body =
            Json::parse(line).map_err(|e| bad("request", format!("not valid JSON: {e}")))?;
        // A missing/mistyped op is deferred to `validate` so the response
        // can still echo the request's id.
        let op = body.get("op").and_then(Json::as_str).unwrap_or("").to_string();
        let id = body.get("id").cloned().unwrap_or(Json::Null);
        Ok(Request { id, op, body, arrival: None })
    }

    /// Admission-time request validation: every known field that is
    /// *present* must have the right type (and λ must be finite and
    /// non-negative); absent fields take their documented defaults. This
    /// runs before queueing/grouping, so the `unwrap_or` defaults in
    /// [`Request::coalesce_key`] and the op handlers are only ever
    /// reached for absent fields — a mistyped rider can never poison a
    /// coalesced group. Failures are typed `bad_request` echoing the
    /// field (docs/ROBUSTNESS.md).
    fn validate(&self) -> Result<()> {
        if self.op.is_empty() {
            return Err(bad("op", "required: a string op (search|perm|sweep|stats|shutdown)"));
        }
        for f in ["seed", "n_perm", "batch", "workers", "limit"] {
            field_usize(&self.body, f, f, 0)?;
        }
        let lambda = field_f64(&self.body, "lambda", "lambda", 0.0)?;
        if lambda < 0.0 {
            return Err(bad("lambda", format!("ridge λ must be ≥ 0, got {lambda}")));
        }
        for f in ["backend", "by", "exp", "scale"] {
            field_str(&self.body, f, f, "")?;
        }
        for f in ["bias_adjust", "return_null"] {
            field_bool(&self.body, f, f)?;
        }
        if let Some(folds) = self.body.get("folds") {
            field_usize(folds, "k", "folds.k", 0)?;
            field_usize(folds, "seed", "folds.seed", 0)?;
        }
        if let Some(syn) = self.body.get("data").and_then(|d| d.get("synthetic")) {
            for f in ["n", "p", "c", "seed"] {
                field_usize(syn, f, &format!("data.synthetic.{f}"), 0)?;
            }
        }
        if let Some(g) = self.body.get("grid") {
            let arr = g
                .as_arr()
                .ok_or_else(|| bad("grid", format!("expected an array, got {}", g.dump())))?;
            for v in arr {
                if !v.as_f64().is_some_and(f64::is_finite) {
                    return Err(bad("grid", format!("expected finite numbers, got {}", v.dump())));
                }
            }
        }
        Ok(())
    }

    /// Merge key for queued `perm` requests (see the module docs); `None`
    /// for every other op and for inline-data perm requests.
    fn coalesce_key(&self) -> Option<String> {
        if self.op != "perm" {
            return None;
        }
        let syn = self.body.get("data")?.get("synthetic")?;
        let n = syn.get("n")?.as_usize()?;
        let p = syn.get("p")?.as_usize()?;
        let c = syn.get("c").and_then(Json::as_usize).unwrap_or(2);
        let dseed = syn.get("seed").and_then(Json::as_usize).unwrap_or(0);
        let k = self.body.get("folds")?.get("k")?.as_usize()?;
        let fseed = fold_seed(&self.body);
        let lambda = self.body.get("lambda").and_then(Json::as_f64).unwrap_or(1.0);
        let bias = truthy(&self.body, "bias_adjust");
        let backend = self.body.get("backend").and_then(Json::as_str).unwrap_or("auto");
        Some(format!(
            "n{n}|p{p}|c{c}|d{dseed}|k{k}|f{fseed}|l{:016x}|b{}|{backend}",
            lambda.to_bits(),
            u8::from(bias)
        ))
    }
}

/// Fold-RNG seed: `folds.seed`, defaulting to 1 (independent of the data
/// stream so equal fold specs reproduce across data sources).
fn fold_seed(body: &Json) -> u64 {
    body.get("folds")
        .and_then(|f| f.get("seed"))
        .and_then(Json::as_usize)
        .unwrap_or(1) as u64
}

fn truthy(body: &Json, key: &str) -> bool {
    matches!(body.get(key), Some(Json::Bool(true)))
}

/// Shared queue state between the reader (caller thread) and the workers.
struct Queue {
    jobs: Mutex<VecDeque<Request>>,
    ready: Condvar,
    open: AtomicBool,
    /// Admission bound (0 = unbounded); see [`ServeConfig::queue_cap`].
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            jobs: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            open: AtomicBool::new(true),
            cap,
        }
    }

    /// Admit a request, or reject with a typed `overloaded` when the
    /// queue is at capacity. `shutdown` is always admitted — a client
    /// must be able to stop an overloaded daemon.
    fn push(&self, req: Request) -> Result<(), FastCvError> {
        let mut q = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
        if self.cap > 0 && q.len() >= self.cap && req.op != "shutdown" {
            return Err(FastCvError::Overloaded { cap: self.cap });
        }
        q.push_back(req);
        drop(q);
        self.ready.notify_one();
        Ok(())
    }

    fn close(&self) {
        self.open.store(false, Ordering::SeqCst);
        self.ready.notify_all();
    }

    /// Block for the next request; drain queued requests sharing its
    /// coalesce key in the same critical section. `None` once the queue is
    /// closed and empty.
    fn next_job(&self) -> Option<(Request, Vec<Request>)> {
        let mut q = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
        // Chaos hook (`serve.queue.panic`): a panic *while holding the
        // jobs lock* poisons the mutex; every serve-layer lock recovers
        // via `PoisonError::into_inner`, and the worker's catch_unwind
        // boundary keeps the thread alive — the chaos suite pins both.
        recover::maybe_panic("serve.queue.panic");
        loop {
            if let Some(head) = q.pop_front() {
                let mut mates = Vec::new();
                if let Some(key) = head.coalesce_key() {
                    let mut rest = VecDeque::with_capacity(q.len());
                    while let Some(r) = q.pop_front() {
                        if r.coalesce_key().as_deref() == Some(key.as_str()) {
                            mates.push(r);
                        } else {
                            rest.push_back(r);
                        }
                    }
                    *q = rest;
                }
                return Some((head, mates));
            }
            if !self.open.load(Ordering::SeqCst) {
                return None;
            }
            q = self.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Server {
    /// Build a server: the store takes the config's budget and (when a
    /// spill directory is configured) demotes LRU entries there.
    pub fn new(config: ServeConfig) -> Server {
        Self::with_clock(config, Box::new(crate::util::monotonic_clock()))
    }

    /// [`Server::new`] with an injected monotonic clock (seconds, any
    /// epoch) — the deadline tests hand in a stepping counter so expiry
    /// is deterministic instead of wall-clock-raced.
    pub fn with_clock(config: ServeConfig, clock: Box<dyn Fn() -> f64 + Send + Sync>) -> Server {
        let store = match config.budget_bytes {
            Some(b) => FactorStore::with_budget(b),
            None => FactorStore::new(),
        };
        let store = match &config.spill_dir {
            Some(dir) => store.with_spill(dir.clone(), 256),
            None => store,
        };
        Server {
            config,
            store,
            coalesced: AtomicU64::new(0),
            clock,
            panics: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// The shared factor store (counters, tests, benches).
    pub fn store(&self) -> &FactorStore {
        &self.store
    }

    /// How many requests rode along in another request's coalesced engine
    /// pass so far (a group of M counts M − 1).
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::SeqCst)
    }

    /// Worker panics caught (and answered `worker_panic`) so far.
    pub fn worker_panics(&self) -> u64 {
        self.panics.load(Ordering::SeqCst)
    }

    /// Requests answered `deadline_exceeded` so far.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::SeqCst)
    }

    /// Requests rejected `overloaded` at queue admission so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::SeqCst)
    }

    /// Serve one NDJSON stream until EOF or a `shutdown` op, fanning
    /// requests over `config.workers` worker threads. Returns `true` if a
    /// `shutdown` op ended the stream (so a socket accept-loop knows to
    /// stop). Malformed lines get an immediate `ok:false` response and do
    /// not enter the queue.
    pub fn serve_stream<R: BufRead, W: Write + Send>(&self, reader: R, writer: W) -> Result<bool> {
        let queue = Queue::new(self.config.queue_cap);
        let out: Mutex<W> = Mutex::new(writer);
        let mut saw_shutdown = false;
        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..self.config.workers.max(1) {
                scope.spawn(|| self.worker_loop(&queue, &out));
            }
            let mut read_all = || -> Result<()> {
                for line in reader.lines() {
                    let line = line.context("reading request stream")?;
                    if line.trim().is_empty() {
                        continue;
                    }
                    // A malformed line answers a typed `bad_request` and
                    // never enters the queue — the connection stays open
                    // for the next line.
                    match Request::parse(&line) {
                        Ok(mut req) => {
                            if let Err(e) = req.validate() {
                                write_line(&out, &error_response_for(&req.id, &e));
                                continue;
                            }
                            req.arrival = Some((self.clock)());
                            let stop = req.op == "shutdown";
                            let id = req.id.clone();
                            if let Err(e) = queue.push(req) {
                                self.rejected.fetch_add(1, Ordering::SeqCst);
                                write_line(&out, &typed_error(&id, &e));
                            } else if stop {
                                saw_shutdown = true;
                                break;
                            }
                        }
                        Err(e) => {
                            write_line(&out, &error_response_for(&Json::Null, &e));
                        }
                    }
                }
                Ok(())
            };
            // Close the queue even on a read error — otherwise the workers
            // (and this scope's join) would block forever on a torn stream.
            let read_result = read_all();
            queue.close();
            read_result
        })?;
        Ok(saw_shutdown)
    }

    /// Bind a Unix socket and serve connections **concurrently** — each
    /// accepted connection gets its own scoped handler thread running
    /// [`Server::serve_stream`], so a client that connects and idles never
    /// blocks the next client (they all share this server's store and
    /// queue semantics per connection). The loop runs until a `shutdown`
    /// op arrives on any connection; the handler then raises the shared
    /// shutdown flag, **severs every other live connection** (so handlers
    /// blocked reading an idle client observe EOF and exit instead of
    /// pinning the scope join forever), and self-connects to unblock the
    /// accept call, which re-checks the flag and stops. A connection that
    /// fails mid-stream (client vanished, torn socket) ends only that
    /// handler — the daemon keeps serving. A pre-existing socket file at
    /// `path` is replaced.
    pub fn serve_unix(&self, path: &std::path::Path) -> Result<()> {
        use std::os::unix::net::{UnixListener, UnixStream};
        std::fs::remove_file(path).ok();
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        let shutdown = AtomicBool::new(false);
        // Live connections by accept id; the shutdown handler walks this
        // to cut idle readers loose.
        let conns: Mutex<BTreeMap<u64, UnixStream>> = Mutex::new(BTreeMap::new());
        let conn_seq = AtomicU64::new(0);
        let sock_path = path.to_path_buf();
        std::thread::scope(|scope| -> Result<()> {
            loop {
                let (conn, _) = listener.accept().context("accepting serve connection")?;
                let id = conn_seq.fetch_add(1, Ordering::SeqCst);
                // Register *before* checking the flag: either this insert
                // lands before the shutdown handler's sever pass (we get
                // severed) or after it (the lock hand-off makes the raised
                // flag visible below) — no connection can slip through
                // unsevered and unchecked.
                if let Ok(c) = conn.try_clone() {
                    conns.lock().unwrap_or_else(PoisonError::into_inner).insert(id, c);
                }
                if shutdown.load(Ordering::SeqCst) {
                    // The wake-up self-connection (or a late client during
                    // teardown): drop it and stop accepting.
                    conns.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
                    break;
                }
                let (shutdown, conns, sock_path) = (&shutdown, &conns, &sock_path);
                scope.spawn(move || {
                    // Ok(true) = this connection carried the shutdown op;
                    // errors are that client's problem, not the daemon's.
                    let carried_shutdown = match conn.try_clone() {
                        Ok(clone) => {
                            let reader = std::io::BufReader::new(clone);
                            matches!(self.serve_stream(reader, conn), Ok(true))
                        }
                        Err(_) => false,
                    };
                    conns.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
                    if carried_shutdown {
                        shutdown.store(true, Ordering::SeqCst);
                        // Sever every still-open connection so its handler
                        // unblocks and the scope can join…
                        let g = conns.lock().unwrap_or_else(PoisonError::into_inner);
                        for c in g.values() {
                            let _ = c.shutdown(std::net::Shutdown::Both);
                        }
                        drop(g);
                        // …and unblock the (possibly idle) accept loop.
                        let _ = UnixStream::connect(sock_path);
                    }
                });
            }
            Ok(())
        })?;
        std::fs::remove_file(path).ok();
        Ok(())
    }

    /// Process a batch of request lines in-process (tests, the
    /// `ablation_serve` bench, one-shot scripting): coalescing applies
    /// across the whole batch, and responses come back **in input order**
    /// (unlike multi-worker streams). Each line yields exactly one
    /// response line.
    pub fn process_batch(&self, lines: &[String]) -> Vec<String> {
        // The error arm carries the request id (when parsing got far
        // enough to recover one) so bad_request responses still echo it.
        let parsed: Vec<Result<Request, (Json, anyhow::Error)>> = lines
            .iter()
            .map(|l| match Request::parse(l) {
                Err(e) => Err((Json::Null, e)),
                // Mistyped fields answer bad_request before grouping, so a
                // bad rider can never poison a coalesced group's responses.
                Ok(req) => match req.validate() {
                    Ok(()) => Ok(req),
                    Err(e) => Err((req.id.clone(), e)),
                },
            })
            .collect();
        let mut responses: Vec<Option<Json>> = (0..lines.len()).map(|_| None).collect();
        for i in 0..parsed.len() {
            if responses[i].is_some() {
                continue;
            }
            match &parsed[i] {
                Err((id, e)) => responses[i] = Some(error_response_for(id, e)),
                Ok(head) => match head.coalesce_key() {
                    None => responses[i] = Some(self.handle_single(head)),
                    Some(key) => {
                        let mut idx = vec![i];
                        for (j, later) in parsed.iter().enumerate().skip(i + 1) {
                            if responses[j].is_none()
                                && later
                                    .as_ref()
                                    .ok()
                                    .and_then(Request::coalesce_key)
                                    .as_deref()
                                    == Some(key.as_str())
                            {
                                idx.push(j);
                            }
                        }
                        let group: Vec<&Request> = idx
                            .iter()
                            .filter_map(|&j| parsed[j].as_ref().ok())
                            .collect();
                        let group_resps = self.handle_perm_group(&group);
                        for (&j, resp) in idx.iter().zip(group_resps) {
                            responses[j] = Some(resp);
                        }
                    }
                },
            }
        }
        responses
            .into_iter()
            .map(|r| r.unwrap_or_else(|| error_response(&Json::Null, "internal: unprocessed slot")).dump())
            .collect()
    }

    fn worker_loop<W: Write>(&self, queue: &Queue, out: &Mutex<W>) {
        loop {
            // Dequeue under its own catch_unwind: the `serve.queue.panic`
            // site fires while holding the jobs lock, and the poisoned
            // mutex must not take this worker (or the daemon) down.
            let job = match recover::run_caught(|| queue.next_job()) {
                Ok(job) => job,
                Err(_) => {
                    self.panics.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
            };
            let Some((head, mates)) = job else { return };
            if head.op == "shutdown" {
                write_line(out, &ok_response(&head.id, "shutdown", BTreeMap::new(), &self.store));
                queue.close();
                continue;
            }
            // Deadline check at dequeue: a request that waited past its
            // budget answers `deadline_exceeded` instead of paying for a
            // factor build nobody is waiting on. Checked per request —
            // coalesced mates that arrived in time still run (as a
            // smaller group).
            let mut all = vec![head];
            all.extend(mates);
            let now = (self.clock)();
            let mut live: Vec<&Request> = Vec::with_capacity(all.len());
            for r in &all {
                match self.expired_deadline(r, now) {
                    Some(deadline_ms) => {
                        self.deadline_misses.fetch_add(1, Ordering::SeqCst);
                        let err = FastCvError::DeadlineExceeded { deadline_ms };
                        write_line(out, &typed_error(&r.id, &err));
                    }
                    None => live.push(r),
                }
            }
            if live.is_empty() {
                continue;
            }
            // Handle under catch_unwind: a panic — injected via the
            // `serve.worker.panic` site or real — answers every request
            // in the job with a typed `worker_panic` and the daemon
            // keeps serving (docs/ROBUSTNESS.md).
            let handled = recover::run_caught(|| {
                recover::maybe_panic("serve.worker.panic");
                if live.len() == 1 && live[0].coalesce_key().is_none() {
                    write_line(out, &self.handle_single(live[0]));
                } else {
                    for resp in self.handle_perm_group(&live) {
                        write_line(out, &resp);
                    }
                }
            });
            if let Err(detail) = handled {
                self.panics.fetch_add(1, Ordering::SeqCst);
                let err = FastCvError::WorkerPanic { detail };
                // Only the requests that were actually running — the
                // deadline-expired ones were already answered above.
                for r in &live {
                    write_line(out, &typed_error(&r.id, &err));
                }
            }
        }
    }

    /// `Some(deadline_ms)` iff a deadline is configured, the request was
    /// stamped at admission, and it has waited longer than the budget.
    fn expired_deadline(&self, req: &Request, now: f64) -> Option<u64> {
        let deadline_ms = self.config.deadline_ms;
        if deadline_ms == 0 {
            return None;
        }
        let arrival = req.arrival?;
        ((now - arrival) * 1000.0 > deadline_ms as f64).then_some(deadline_ms)
    }

    /// One non-coalesced request → one response (never panics; errors
    /// become `ok:false` responses).
    fn handle_single(&self, req: &Request) -> Json {
        let result = match req.op.as_str() {
            "search" => self.op_search(req),
            "perm" => self
                .handle_perm_group(&[req])
                .pop()
                .ok_or_else(|| anyhow!("internal: empty perm group")),
            "sweep" => self.op_sweep(req),
            "stats" => self.op_stats(req),
            "shutdown" => Ok(ok_response(&req.id, "shutdown", BTreeMap::new(), &self.store)),
            other => {
                Err(bad("op", format!("unknown op {other:?} (search|perm|sweep|stats|shutdown)")))
            }
        };
        match result {
            Ok(resp) => resp,
            Err(e) => error_response_for(&req.id, &e),
        }
    }

    /// A group of perm requests sharing one coalesce key → one jobs-engine
    /// pass → one response per request, in group order. Also the single
    /// perm path (group of one).
    fn handle_perm_group(&self, group: &[&Request]) -> Vec<Json> {
        match self.run_perm_group(group) {
            Ok(resps) => resps,
            Err(e) => group.iter().map(|r| error_response_for(&r.id, &e)).collect(),
        }
    }

    fn run_perm_group(&self, group: &[&Request]) -> Result<Vec<Json>> {
        let head = group.first().ok_or_else(|| anyhow!("internal: empty perm group"))?;
        let (ds, folds) = parse_dataset_and_folds(&head.body)?;
        // Absent fields default; present-but-mistyped ones were already
        // rejected at admission (`Request::validate`) — these helpers are
        // the same check again as defense in depth.
        let lambda = field_f64(&head.body, "lambda", "lambda", 1.0)?;
        let bias = field_bool(&head.body, "bias_adjust", "bias_adjust")?;
        let batch = field_usize(&head.body, "batch", "batch", 64)?;
        // Per-request anchors: the first draw of each request's RNG — the
        // exact draw a standalone engine run with that seed would make.
        let jobs: Vec<PermJob> = group
            .iter()
            .map(|r| -> Result<PermJob> {
                let seed = field_usize(&r.body, "seed", "seed", 0)? as u64;
                let n_perm = field_usize(&r.body, "n_perm", "n_perm", 100)?;
                Ok(PermJob { anchor: Rng::new(seed).next_u64(), n_perm })
            })
            .collect::<Result<_>>()?;
        let (ctx, resolved) =
            self.request_ctx(&head.body, ds.x.rows(), ds.x.cols(), usize::from(lambda > 0.0))?;
        let strategy = BatchStrategy::new(batch.max(1), self.config.threads.max(1));
        let results = if ds.n_classes == 2 {
            analytic_binary_permutation_jobs_ctx(
                &ds.x, &ds.labels, &folds, lambda, &jobs, bias, strategy, &ctx,
            )?
        } else {
            analytic_multiclass_permutation_jobs_ctx(
                &ds.x, &ds.labels, ds.n_classes, &folds, lambda, &jobs, strategy, &ctx,
            )?
        };
        self.coalesced.fetch_add(group.len() as u64 - 1, Ordering::SeqCst);
        Ok(group
            .iter()
            .zip(results)
            .map(|(req, res)| {
                let mut extra = BTreeMap::new();
                extra.insert("observed".into(), Json::Num(res.observed));
                extra.insert("p_value".into(), Json::Num(res.p_value));
                extra.insert("n_perm".into(), Json::Num(res.null.len() as f64));
                extra.insert("backend".into(), Json::Str(resolved.tag().to_string()));
                extra.insert("coalesced".into(), Json::Num(group.len() as f64));
                if truthy(&req.body, "return_null") {
                    extra.insert(
                        "null".into(),
                        Json::Arr(res.null.iter().map(|&v| Json::Num(v)).collect()),
                    );
                }
                ok_response(&req.id, "perm", extra, &self.store)
            })
            .collect())
    }

    fn op_search(&self, req: &Request) -> Result<Json> {
        let (ds, folds) = parse_dataset_and_folds(&req.body)?;
        let grid_vals: Vec<f64> = match req.body.get("grid").and_then(Json::as_arr) {
            Some(arr) => arr.iter().filter_map(Json::as_f64).collect(),
            None => vec![0.01, 0.1, 1.0, 10.0, 100.0],
        };
        if grid_vals.is_empty() {
            return Err(bad("grid", "must hold at least one number"));
        }
        let by = match field_str(&req.body, "by", "by", "accuracy")? {
            "accuracy" => SelectBy::Accuracy,
            "auc" => SelectBy::Auc,
            "negmse" => SelectBy::NegMse,
            other => return Err(bad("by", format!("unknown {other:?} (accuracy|auc|negmse)"))),
        };
        let positives = grid_vals.iter().filter(|&&l| l > 0.0).count();
        let (ctx, resolved) =
            self.request_ctx(&req.body, ds.x.rows(), ds.x.cols(), positives)?;
        let search = if ds.n_classes == 2 {
            let y = signed_codes(&ds.labels);
            search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid_vals, by, &ctx)?
        } else {
            search_lambda_multiclass(&ds.x, &ds.labels, ds.n_classes, &folds, &grid_vals, &ctx)?
        };
        let mut extra = BTreeMap::new();
        extra.insert("lambda".into(), Json::Num(search.best_lambda()));
        extra.insert("score".into(), Json::Num(search.scores[search.best].score));
        extra.insert("backend".into(), Json::Str(resolved.tag().to_string()));
        extra.insert(
            "scores".into(),
            Json::Arr(
                search
                    .scores
                    .iter()
                    .map(|s| {
                        let mut o = BTreeMap::new();
                        o.insert("lambda".into(), Json::Num(s.lambda));
                        o.insert("score".into(), Json::Num(s.score));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Ok(ok_response(&req.id, "search", extra, &self.store))
    }

    fn op_sweep(&self, req: &Request) -> Result<Json> {
        let tag = field_str(&req.body, "exp", "exp", "f3a")?.to_string();
        let exp = Experiment::from_tag(&tag)
            .ok_or_else(|| bad("exp", format!("unknown experiment {tag:?} (f3a..f3d)")))?;
        let scale = match field_str(&req.body, "scale", "scale", "tiny")? {
            "paper" => SweepScale::paper(),
            "medium" => SweepScale::medium(),
            _ => SweepScale::tiny(),
        };
        let seed = field_usize(&req.body, "seed", "seed", 2018)? as u64;
        let workers = field_usize(&req.body, "workers", "workers", 1)?;
        let backend_tag = field_str(&req.body, "backend", "backend", "primal")?.to_string();
        let backend = GramBackend::from_tag(&backend_tag)
            .ok_or_else(|| bad("backend", format!("unknown backend {backend_tag:?}")))?;
        let mut points = grid(exp, &scale);
        if let Some(limit) = req.body.get("limit").and_then(Json::as_usize) {
            points.truncate(limit);
        }
        for p in points.iter_mut() {
            p.backend = backend;
            p.threads = self.config.threads;
            p.tile = self.config.tile.clone();
            p.engine = PermEngine::Serial;
        }
        let sched = Scheduler::new(workers.max(1), seed, false);
        let clock = crate::util::monotonic_clock();
        let results = sched.run_clocked(&points, &clock, Some(&self.store));
        let report = SweepReport::new(results);
        let mut extra = BTreeMap::new();
        extra.insert("points".into(), Json::Num(points.len() as f64));
        extra.insert("tsv".into(), Json::Str(report.to_tsv()));
        Ok(ok_response(&req.id, "sweep", extra, &self.store))
    }

    fn op_stats(&self, req: &Request) -> Result<Json> {
        let s = self.store.stats();
        let mut extra = BTreeMap::new();
        extra.insert("hits".into(), Json::Num(s.hits as f64));
        extra.insert("misses".into(), Json::Num(s.misses as f64));
        extra.insert("evictions".into(), Json::Num(s.evictions as f64));
        extra.insert("demotions".into(), Json::Num(s.demotions as f64));
        extra.insert("entries".into(), Json::Num(s.entries as f64));
        extra.insert("resident_bytes".into(), Json::Num(s.resident_bytes as f64));
        extra.insert("coalesced".into(), Json::Num(self.coalesced() as f64));
        // Robustness counters (docs/ROBUSTNESS.md): corruption recoveries
        // in the store, plus this server's caught panics / expired
        // deadlines / admission rejections.
        extra.insert("corruptions".into(), Json::Num(s.corruptions as f64));
        extra.insert("worker_panics".into(), Json::Num(self.worker_panics() as f64));
        extra.insert("deadline_exceeded".into(), Json::Num(self.deadline_misses() as f64));
        extra.insert("overloaded".into(), Json::Num(self.rejected() as f64));
        if let Some(b) = s.budget_bytes {
            extra.insert("budget_bytes".into(), Json::Num(b as f64));
        }
        Ok(ok_response(&req.id, "stats", extra, &self.store))
    }

    /// Build the per-request [`ComputeContext`]: the server's pool/tile/
    /// store plus the request's backend policy resolved for its shape —
    /// `auto` resolves through [`ComputeContext::resolve_for_grid`], so a
    /// spill-configured server steers Auto λ-grids to the fully
    /// streamable dual cache exactly like the CLI.
    fn request_ctx(
        &self,
        body: &Json,
        n: usize,
        p: usize,
        positives: usize,
    ) -> Result<(ComputeContext<'_>, GramBackend)> {
        let tag = field_str(body, "backend", "backend", "auto")?.to_string();
        let policy = GramBackend::from_tag(&tag)
            .ok_or_else(|| bad("backend", format!("unknown backend {tag:?} (primal|dual|spectral|auto)")))?;
        let base = ComputeContext::with_threads(self.config.threads)
            .with_backend(policy)
            .with_tile_policy(self.config.tile.clone())
            .with_store(&self.store);
        let resolved = base.resolve_for_grid(n, p, positives.max(1));
        Ok((base.with_backend(resolved), resolved))
    }
}

/// Parse the request's dataset + folds: synthetic
/// (`{"data":{"synthetic":{n,p,c,seed}}}`) or inline
/// (`{"data":{"x":[[…]],"labels":[…]}}`), folds `{"k":K,"seed":S}` —
/// k-fold for binary, stratified for multi-class, drawn from
/// `Rng::new(folds.seed)` (default 1) so equal fold specs reproduce.
fn parse_dataset_and_folds(body: &Json) -> Result<(Dataset, Vec<Vec<usize>>)> {
    let data = body.get("data").ok_or_else(|| bad("data", "required: a \"data\" object"))?;
    let ds = if let Some(syn) = data.get("synthetic") {
        let n = syn
            .get("n")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("data.synthetic.n", "required: a positive sample count"))?;
        let p = syn
            .get("p")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("data.synthetic.p", "required: a positive feature count"))?;
        let c = syn.get("c").and_then(Json::as_usize).unwrap_or(2);
        let seed = syn.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
        let spec = if c == 2 {
            SyntheticSpec::binary(n, p)
        } else {
            SyntheticSpec::multiclass(n, p, c)
        };
        generate(&spec, &mut Rng::new(seed))
    } else {
        let rows = data
            .get("x")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("data", "needs \"synthetic\" or inline \"x\" rows"))?;
        let labels: Vec<usize> = data
            .get("labels")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("data.labels", "required with inline \"x\""))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| bad("data.labels", "labels must be non-negative integers"))
            })
            .collect::<Result<_>>()?;
        let n = rows.len();
        if n == 0 || n != labels.len() {
            return Err(bad(
                "data.x",
                format!("inline x/labels shape mismatch ({n} rows, {} labels)", labels.len()),
            ));
        }
        let p = rows[0].as_arr().map_or(0, <[Json]>::len);
        if p == 0 {
            return Err(bad("data.x", "rows must be non-empty arrays"));
        }
        let mut x = Mat::zeros(n, p);
        for (i, row) in rows.iter().enumerate() {
            let vals = row
                .as_arr()
                .ok_or_else(|| bad("data.x", format!("row {i} is not an array")))?;
            if vals.len() != p {
                return Err(bad(
                    "data.x",
                    format!("row {i} has {} cols, expected {p}", vals.len()),
                ));
            }
            for (j, v) in vals.iter().enumerate() {
                x[(i, j)] = v
                    .as_f64()
                    .ok_or_else(|| bad("data.x", format!("x[{i}][{j}] is not a number")))?;
            }
        }
        let c = data
            .get("c")
            .and_then(Json::as_usize)
            .unwrap_or_else(|| labels.iter().max().map_or(2, |&m| (m + 1).max(2)));
        Dataset { x, labels, n_classes: c }
    };
    let k = body
        .get("folds")
        .and_then(|f| f.get("k"))
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("folds.k", "required: folds {\"k\": K}"))?;
    if !(2..=ds.n()).contains(&k) {
        return Err(bad("folds.k", format!("k={k} out of range for n={}", ds.n())));
    }
    let mut frng = Rng::new(fold_seed(body));
    let folds = if ds.n_classes == 2 {
        kfold(ds.n(), k, &mut frng)
    } else {
        stratified_kfold(&ds.labels, k, &mut frng)
    };
    Ok((ds, folds))
}

/// `{"id":…, "ok":true, "op":…, …extra…, "cache":"h…/m…/e…/d…"}` — every
/// success response carries the store's counter tag (satellite: counters
/// surface in serve responses).
fn ok_response(id: &Json, op: &str, extra: BTreeMap<String, Json>, store: &FactorStore) -> Json {
    let mut obj = extra;
    obj.insert("id".into(), id.clone());
    obj.insert("ok".into(), Json::Bool(true));
    obj.insert("op".into(), Json::Str(op.to_string()));
    obj.insert("cache".into(), Json::Str(store.stats().tag()));
    Json::Obj(obj)
}

/// `{"id":…, "ok":false, "error":…}`.
fn error_response(id: &Json, msg: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("id".into(), id.clone());
    obj.insert("ok".into(), Json::Bool(false));
    obj.insert("error".into(), Json::Str(msg.to_string()));
    Json::Obj(obj)
}

/// [`error_response`] plus the machine-readable `"kind"` (and, for
/// `bad_request`, the offending `"field"`) when the error chain holds a
/// typed [`FastCvError`] — the serve side of docs/ROBUSTNESS.md's
/// taxonomy. Untyped errors keep the plain `{"error": …}` shape.
fn error_response_for(id: &Json, err: &anyhow::Error) -> Json {
    let mut resp = error_response(id, &format!("{err:#}"));
    if let (Json::Obj(obj), Some(fe)) = (&mut resp, err.downcast_ref::<FastCvError>()) {
        obj.insert("kind".into(), Json::Str(fe.kind().to_string()));
        if let Some(f) = fe.field() {
            obj.insert("field".into(), Json::Str(f.to_string()));
        }
    }
    resp
}

/// [`error_response_for`] for a bare typed error (deadline, overload,
/// worker panic — the paths that never went through `anyhow`).
fn typed_error(id: &Json, err: &FastCvError) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("id".into(), id.clone());
    obj.insert("ok".into(), Json::Bool(false));
    obj.insert("error".into(), Json::Str(err.to_string()));
    obj.insert("kind".into(), Json::Str(err.kind().to_string()));
    if let Some(f) = err.field() {
        obj.insert("field".into(), Json::Str(f.to_string()));
    }
    Json::Obj(obj)
}

fn write_line<W: Write>(out: &Mutex<W>, resp: &Json) {
    // Chaos hook (`serve.conn.drop`): a client whose connection died
    // loses its response, never the daemon — the write is skipped exactly
    // as if the OS had swallowed it.
    if fault::hit("serve.conn.drop").is_some() {
        return;
    }
    let mut w = out.lock().unwrap_or_else(PoisonError::into_inner);
    // A torn-down client is not a server error: drop the response.
    let _ = writeln!(w, "{}", resp.dump());
    let _ = w.flush();
}

/// A [`StoreStats`] counter snapshot rendered as the serve/TSV tag —
/// exported for the bench harness so it does not reach into the store.
pub fn stats_tag(stats: &StoreStats) -> String {
    stats.tag()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        s.to_string()
    }

    fn parse_ok(resp: &str) -> Json {
        let v = Json::parse(resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        v
    }

    #[test]
    fn stats_shutdown_and_errors_roundtrip() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[
            line(r#"{"id":1,"op":"stats"}"#),
            line("not json"),
            line(r#"{"id":2,"op":"frobnicate"}"#),
            line(r#"{"id":3,"op":"shutdown"}"#),
        ]);
        assert_eq!(out.len(), 4);
        let stats = parse_ok(&out[0]);
        assert_eq!(stats.get("id").and_then(Json::as_f64), Some(1.0));
        assert_eq!(stats.get("hits").and_then(Json::as_f64), Some(0.0));
        assert!(stats.get("cache").and_then(Json::as_str).is_some());
        let bad = Json::parse(&out[1]).unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        let unknown = Json::parse(&out[2]).unwrap();
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
        assert!(unknown.get("error").and_then(Json::as_str).is_some());
        parse_ok(&out[3]);
    }

    #[test]
    fn perm_requests_coalesce_and_match_standalone_runs() {
        // Two queued perm requests on one key run as a single engine pass
        // and still answer exactly what standalone servers answer.
        let req_a = line(
            r#"{"id":"a","op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100,"return_null":true}"#,
        );
        let req_b = line(
            r#"{"id":"b","op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":101,"return_null":true}"#,
        );
        let merged_server = Server::new(ServeConfig::default());
        let merged = merged_server.process_batch(&[req_a.clone(), req_b.clone()]);
        assert_eq!(merged_server.coalesced(), 1, "one rider in the merged pass");
        let solo_a = Server::new(ServeConfig::default()).process_batch(&[req_a])[0].clone();
        let solo_b = Server::new(ServeConfig::default()).process_batch(&[req_b])[0].clone();
        for (got, want) in [(&merged[0], &solo_a), (&merged[1], &solo_b)] {
            let g = parse_ok(got);
            let w = parse_ok(want);
            assert_eq!(g.get("observed"), w.get("observed"));
            assert_eq!(g.get("p_value"), w.get("p_value"));
            assert_eq!(g.get("null"), w.get("null"), "coalesced null must be bitwise equal");
        }
        let g0 = parse_ok(&merged[0]);
        assert_eq!(g0.get("coalesced").and_then(Json::as_f64), Some(2.0));
        // Requests on a *different* key must not join the group.
        let other = Server::new(ServeConfig::default());
        let out = other.process_batch(&[
            line(
                r#"{"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":4,"seed":1}"#,
            ),
            line(
                r#"{"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":6}},"folds":{"k":4},"lambda":1.0,"n_perm":4,"seed":1}"#,
            ),
        ]);
        assert_eq!(out.len(), 2);
        assert_eq!(other.coalesced(), 0, "different data seeds must not merge");
    }

    #[test]
    fn warm_store_serves_repeat_requests_from_cache() {
        let server = Server::new(ServeConfig::default());
        let req = line(
            r#"{"op":"perm","data":{"synthetic":{"n":20,"p":30,"seed":9}},"folds":{"k":4},"lambda":0.5,"n_perm":5,"seed":7}"#,
        );
        let cold = server.process_batch(&[req.clone()]);
        let cold_stats = server.store().stats();
        assert!(cold_stats.misses >= 1 && cold_stats.hits == 0, "{cold_stats:?}");
        let warm = server.process_batch(&[req]);
        let warm_stats = server.store().stats();
        assert!(warm_stats.hits >= 1, "repeat request must hit: {warm_stats:?}");
        // Warm answers are byte-identical to cold ones (modulo the cache
        // tag, which is allowed to move).
        let c = parse_ok(&cold[0]);
        let w = parse_ok(&warm[0]);
        assert_eq!(c.get("observed"), w.get("observed"));
        assert_eq!(c.get("p_value"), w.get("p_value"));
    }

    #[test]
    fn search_op_selects_from_grid_and_reports_backend() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[line(
            r#"{"op":"search","data":{"synthetic":{"n":30,"p":50,"seed":3}},"folds":{"k":5},"grid":[0.1,1.0,10.0]}"#,
        )]);
        let v = parse_ok(&out[0]);
        let lambda = v.get("lambda").and_then(Json::as_f64).unwrap();
        assert!([0.1, 1.0, 10.0].contains(&lambda), "winner {lambda} must come from the grid");
        // P > N with ≥2 positive candidates → Auto resolves to spectral.
        assert_eq!(v.get("backend").and_then(Json::as_str), Some("spectral"));
        assert_eq!(v.get("scores").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        // multi-class arm
        let out = server.process_batch(&[line(
            r#"{"op":"search","data":{"synthetic":{"n":40,"p":10,"c":4,"seed":3}},"folds":{"k":4},"grid":[0.5,5.0]}"#,
        )]);
        let v = parse_ok(&out[0]);
        assert!(v.get("lambda").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn inline_data_perm_runs_without_coalescing() {
        let server = Server::new(ServeConfig::default());
        let req = line(
            r#"{"op":"perm","data":{"x":[[0.1,1.2],[1.3,-0.4],[0.5,0.9],[-1.1,0.2],[0.7,1.1],[1.2,-0.8]],"labels":[0,1,0,1,0,1]},"folds":{"k":3},"lambda":1.0,"n_perm":4,"seed":2}"#,
        );
        let out = server.process_batch(&[req.clone(), req]);
        assert_eq!(out.len(), 2);
        let a = parse_ok(&out[0]);
        let b = parse_ok(&out[1]);
        assert_eq!(a.get("observed"), b.get("observed"));
        assert_eq!(server.coalesced(), 0, "inline data never merges");
        assert_eq!(a.get("coalesced").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn sweep_op_returns_tsv_and_shares_the_store() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[line(
            r#"{"op":"sweep","exp":"f3a","scale":"tiny","seed":2018,"limit":6}"#,
        )]);
        let v = parse_ok(&out[0]);
        assert_eq!(v.get("points").and_then(Json::as_usize), Some(6));
        let tsv = v.get("tsv").and_then(Json::as_str).unwrap();
        assert_eq!(tsv.lines().count(), 7, "header + 6 rows");
        assert!(tsv.starts_with("exp\t"), "{tsv}");
        // The first six tiny f3a points share one (n,p,rep) dataset across
        // fold counts → the scheduler's canonical-seed sharing must score
        // real store hits.
        let s = server.store().stats();
        assert!(s.hits >= 1, "sweep points sharing a dataset must share factors: {s:?}");
        // And the sweep is reproducible through a fresh server.
        let again = Server::new(ServeConfig::default()).process_batch(&[line(
            r#"{"op":"sweep","exp":"f3a","scale":"tiny","seed":2018,"limit":6}"#,
        )]);
        let v2 = parse_ok(&again[0]);
        let strip_timing = |t: &str| -> Vec<String> {
            t.lines()
                .map(|l| {
                    l.split('\t')
                        .enumerate()
                        .filter(|(i, _)| ![11, 12, 13, 14].contains(i))
                        .map(|(_, f)| f.to_string())
                        .collect::<Vec<_>>()
                        .join("\t")
                })
                .collect()
        };
        assert_eq!(
            strip_timing(tsv),
            strip_timing(v2.get("tsv").and_then(Json::as_str).unwrap()),
            "non-timing sweep columns must reproduce"
        );
    }

    #[test]
    fn serve_unix_overlapping_clients_are_served_concurrently() {
        // Client A connects first and goes idle; client B connects while A
        // is still open and expects an answer. Under the old sequential
        // accept loop B would block behind A forever — the read timeout
        // below turns that regression into a test failure instead of a
        // hang. A then carries the shutdown op that stops the daemon.
        use std::io::{BufRead as _, BufReader, Write as _};
        use std::os::unix::net::UnixStream;
        use std::time::Duration;
        let dir = std::env::temp_dir()
            .join(format!("fastcv_serve_unix_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("s.sock");
        let server = Server::new(ServeConfig::default());
        std::thread::scope(|scope| {
            let daemon = scope.spawn(|| server.serve_unix(&sock));
            let connect = || {
                for _ in 0..500 {
                    if let Ok(c) = UnixStream::connect(&sock) {
                        return c;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                panic!("serve_unix socket never came up");
            };
            let mut a = connect();
            let mut b = connect();
            b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            writeln!(b, r#"{{"id":"b","op":"stats"}}"#).unwrap();
            b.flush().unwrap();
            let mut b_reader = BufReader::new(b.try_clone().unwrap());
            let mut resp = String::new();
            b_reader.read_line(&mut resp).expect("B must be answered while A idles");
            let v = parse_ok(&resp);
            assert_eq!(v.get("id").and_then(Json::as_str), Some("b"));
            // A is still connected; now it shuts the daemon down. B stays
            // connected and idle the whole time — the daemon must sever
            // B's connection itself rather than wait on it, so the join
            // below completes while B is still open.
            a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            writeln!(a, r#"{{"id":"a","op":"shutdown"}}"#).unwrap();
            a.flush().unwrap();
            let mut a_reader = BufReader::new(a.try_clone().unwrap());
            let mut resp = String::new();
            a_reader.read_line(&mut resp).unwrap();
            parse_ok(&resp);
            daemon.join().unwrap().unwrap();
            assert!(!sock.exists(), "socket file must be removed on shutdown");
            // The severed idle client reads EOF, not a hang.
            let mut tail = String::new();
            assert_eq!(b_reader.read_line(&mut tail).unwrap(), 0, "B must see EOF");
            drop(a);
            drop(b);
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_requests_answer_typed_fields_and_never_drop_the_stream() {
        // One garbage line and one mistyped field, sandwiched between
        // valid requests: every line gets an answer, the bad ones carry
        // kind/field, and the stream keeps serving afterwards.
        let server = Server::new(ServeConfig::default());
        let input = [
            r#"{"id":1,"op":"stats"}"#,
            "this is not json",
            r#"{"id":3,"op":"perm","data":{"synthetic":{"n":20,"p":8,"seed":4}},"folds":{"k":4},"lambda":"abc"}"#,
            r#"{"id":4,"op":"stats"}"#,
            r#"{"id":5,"op":"shutdown"}"#,
        ]
        .join("\n");
        let mut out: Vec<u8> = Vec::new();
        let shut = server
            .serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        assert!(shut, "the stream must reach the shutdown op");
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(responses.len(), 5, "{text}");
        let by_id = |id: f64| {
            responses
                .iter()
                .find(|r| r.get("id").and_then(Json::as_f64) == Some(id))
                .unwrap_or_else(|| panic!("no response with id {id}: {text}"))
        };
        assert_eq!(by_id(1.0).get("ok"), Some(&Json::Bool(true)));
        assert_eq!(by_id(4.0).get("ok"), Some(&Json::Bool(true)));
        // The garbage line has no recoverable id; find it by kind.
        let garbage = responses
            .iter()
            .find(|r| r.get("id") == Some(&Json::Null))
            .expect("garbage line must still be answered");
        assert_eq!(garbage.get("kind").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(garbage.get("field").and_then(Json::as_str), Some("request"));
        let mistyped = by_id(3.0);
        assert_eq!(mistyped.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(mistyped.get("kind").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(mistyped.get("field").and_then(Json::as_str), Some("lambda"));
    }

    #[test]
    fn mistyped_fields_fail_in_batch_with_the_offending_field() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[
            line(r#"{"id":1,"op":"perm","data":{"synthetic":{"n":20,"p":8}},"folds":{"k":"four"},"n_perm":2}"#),
            line(r#"{"id":2,"op":"search","data":{"synthetic":{"n":20,"p":8}},"folds":{"k":4},"grid":[0.1,"x"]}"#),
            line(r#"{"id":3,"op":"perm","data":{"synthetic":{"n":20,"p":8}},"folds":{"k":4},"lambda":-1.0,"n_perm":2}"#),
            line(r#"{"id":4,"op":"sweep","exp":"nope"}"#),
            line(r#"{"id":5}"#),
        ]);
        for (resp, field) in out.iter().zip(["folds.k", "grid", "lambda", "exp", "op"]) {
            let v = Json::parse(resp).unwrap();
            assert_eq!(v.get("ok"), Some(&Json::Bool(false)), "{resp}");
            assert_eq!(v.get("kind").and_then(Json::as_str), Some("bad_request"), "{resp}");
            assert_eq!(v.get("field").and_then(Json::as_str), Some(field), "{resp}");
            let msg = v.get("error").and_then(Json::as_str).unwrap();
            assert!(msg.contains(field), "message must echo the field: {msg}");
        }
    }

    #[test]
    fn queue_cap_rejects_at_admission_but_always_admits_shutdown() {
        let queue = Queue::new(2);
        let req = |op: &str| Request::parse(&format!(r#"{{"op":"{op}"}}"#)).unwrap();
        assert!(queue.push(req("stats")).is_ok());
        assert!(queue.push(req("stats")).is_ok());
        let err = queue.push(req("stats")).unwrap_err();
        assert_eq!(err.kind(), "overloaded");
        assert!(err.is_retryable(), "overload must invite a retry");
        // The stop signal cannot be locked out by a full queue.
        assert!(queue.push(req("shutdown")).is_ok());
    }

    #[test]
    fn chaos_worker_panic_answers_typed_and_the_daemon_keeps_serving() {
        use crate::fastcv::fault::{install, FaultPlan};
        let _scope = install(FaultPlan::parse("serve.worker.panic@1").unwrap());
        let server = Server::new(ServeConfig::default());
        let input = [
            r#"{"id":1,"op":"stats"}"#,
            r#"{"id":2,"op":"stats"}"#,
            r#"{"id":3,"op":"shutdown"}"#,
        ]
        .join("\n");
        let mut out: Vec<u8> = Vec::new();
        server.serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(responses.len(), 3, "{text}");
        let first = responses
            .iter()
            .find(|r| r.get("id").and_then(Json::as_f64) == Some(1.0))
            .unwrap();
        assert_eq!(first.get("ok"), Some(&Json::Bool(false)), "{text}");
        assert_eq!(first.get("kind").and_then(Json::as_str), Some("worker_panic"));
        let second = responses
            .iter()
            .find(|r| r.get("id").and_then(Json::as_f64) == Some(2.0))
            .unwrap();
        assert_eq!(second.get("ok"), Some(&Json::Bool(true)), "daemon must keep serving");
        assert_eq!(server.worker_panics(), 1);
    }

    #[test]
    fn chaos_queue_panic_poisons_the_jobs_mutex_and_recovery_serves_on() {
        // The injected panic fires *inside* next_job's critical section,
        // poisoning the jobs mutex. Every serve lock recovers via
        // PoisonError::into_inner and the worker's catch_unwind keeps the
        // thread alive — both requests still get answered.
        use crate::fastcv::fault::{install, FaultPlan};
        let _scope = install(FaultPlan::parse("serve.queue.panic@1").unwrap());
        let server = Server::new(ServeConfig::default());
        let input = [r#"{"id":1,"op":"stats"}"#, r#"{"id":2,"op":"shutdown"}"#].join("\n");
        let mut out: Vec<u8> = Vec::new();
        let shut = server
            .serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        assert!(shut);
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(responses.len(), 2, "{text}");
        for r in &responses {
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{text}");
        }
        assert!(server.worker_panics() >= 1, "the poisoning panic must be counted");
    }

    #[test]
    fn chaos_expired_deadlines_answer_typed_without_paying_for_a_build() {
        use std::sync::atomic::AtomicU64;
        use std::sync::Arc;
        // A stepping fake clock: every reading is one second after the
        // previous one, so any request's dequeue is ≥ 1000 ms after its
        // admission stamp — deterministically past a 100 ms deadline.
        let ticks = Arc::new(AtomicU64::new(0));
        let clock_ticks = Arc::clone(&ticks);
        let config = ServeConfig { deadline_ms: 100, ..ServeConfig::default() };
        let server = Server::with_clock(
            config,
            Box::new(move || clock_ticks.fetch_add(1, Ordering::SeqCst) as f64),
        );
        let input = [
            r#"{"id":1,"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100}"#,
            r#"{"id":2,"op":"shutdown"}"#,
        ]
        .join("\n");
        let mut out: Vec<u8> = Vec::new();
        server.serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(responses.len(), 2, "{text}");
        let perm = responses
            .iter()
            .find(|r| r.get("id").and_then(Json::as_f64) == Some(1.0))
            .unwrap();
        assert_eq!(perm.get("kind").and_then(Json::as_str), Some("deadline_exceeded"));
        assert_eq!(server.deadline_misses(), 1);
        // The expired request never reached the engines: no factor build.
        let s = server.store().stats();
        assert_eq!((s.hits, s.misses), (0, 0), "{s:?}");
        // Counters surface through the stats op on a fresh (deadline-free)
        // server sharing nothing — here just check the field exists.
        let stats_out = server.process_batch(&[line(r#"{"id":9,"op":"stats"}"#)]);
        let v = parse_ok(&stats_out[0]);
        assert_eq!(v.get("deadline_exceeded").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("worker_panics").and_then(Json::as_f64), Some(0.0));
        assert_eq!(v.get("overloaded").and_then(Json::as_f64), Some(0.0));
        assert_eq!(v.get("corruptions").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn serve_stream_answers_every_request_and_stops_on_shutdown() {
        let server = Server::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let input = [
            r#"{"id":1,"op":"stats"}"#,
            r#"{"id":2,"op":"perm","data":{"synthetic":{"n":20,"p":8,"seed":4}},"folds":{"k":4},"lambda":1.0,"n_perm":3,"seed":11}"#,
            r#"{"id":3,"op":"perm","data":{"synthetic":{"n":20,"p":8,"seed":4}},"folds":{"k":4},"lambda":1.0,"n_perm":3,"seed":12}"#,
            r#"{"id":4,"op":"shutdown"}"#,
            r#"{"id":5,"op":"stats"}"#,
        ]
        .join("\n");
        let mut out: Vec<u8> = Vec::new();
        let shut = server
            .serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        assert!(shut, "shutdown op must be reported");
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        // Requests after shutdown are never read: exactly 4 responses.
        assert_eq!(responses.len(), 4, "{text}");
        let mut ids: Vec<f64> =
            responses.iter().filter_map(|r| r.get("id").and_then(Json::as_f64)).collect();
        ids.sort_by(f64::total_cmp);
        assert_eq!(ids, vec![1.0, 2.0, 3.0, 4.0]);
        for r in &responses {
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{text}");
        }
    }
}
