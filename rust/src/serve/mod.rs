//! # `fastcv serve` — a threaded job queue over a shared [`FactorStore`]
//!
//! The sweep CLI amortises factor builds *within* one process invocation;
//! this module amortises them *across* requests: a long-lived daemon owns
//! one [`FactorStore`] and a pool of request workers, so every search /
//! permutation / sweep request that lands on the same dataset key reuses
//! the factors earlier requests paid for. Protocol, keying, eviction, and
//! coalescing semantics are documented in `docs/SERVE.md`.
//!
//! ## Protocol
//!
//! Newline-delimited JSON (NDJSON): one request object per line on stdin
//! (or a Unix socket via `--socket`), one response object per line out.
//! Every response carries the request's `id` (echoed verbatim), `"ok"`,
//! and a `"cache"` counter tag ([`StoreStats::tag`]). With more than one
//! worker, response *order* is not guaranteed — match responses to
//! requests by `id`.
//!
//! Ops: `search` (λ grid through
//! [`search_lambda_ctx`](crate::fastcv::lambda_search::search_lambda_ctx)),
//! `perm` (binary/multi-class permutation test), `sweep` (a Fig. 3 grid
//! through the coordinator's [`Scheduler`] sharing this server's store),
//! `stats` (store counters), `shutdown`.
//!
//! ## Coalescing
//!
//! Queued `perm` requests with an equal coalesce key — synthetic dataset
//! spec × fold spec × λ bits × bias × backend policy × tile tag — are
//! drained together and run as **one** pass of the jobs engine
//! ([`analytic_binary_permutation_jobs_ctx`]): one hat build, one fold
//! prep, one GEMM stream spanning every request's permutation columns.
//! Each request keeps its own determinism anchor
//! (`Rng::new(seed).next_u64()`), so its null distribution is
//! **bit-identical** to a standalone run with that seed (the jobs-engine
//! property tests). Requests with inline (non-synthetic) data are never
//! coalesced — fingerprinting them for a merge key would cost more than
//! the merge saves on typical inline payloads.
//!
//! ## Determinism
//!
//! No wall time or OS entropy feeds any result: datasets come from seeded
//! [`Rng`] streams, folds from a seeded fold RNG, permutation anchors from
//! request seeds. The store is a pure wall-clock/memory knob (its bitwise
//! contract), so a warm cache serves byte-identical results to a cold one.

use crate::coordinator::sweep::{grid, Experiment, PermEngine, SweepScale};
use crate::coordinator::{Scheduler, SweepReport};
use crate::cv::folds::{kfold, stratified_kfold};
use crate::data::synthetic::{generate, SyntheticSpec};
use crate::data::Dataset;
use crate::fastcv::hat::GramBackend;
use crate::fastcv::lambda_search::{
    search_lambda_ctx, search_lambda_multiclass, SelectBy,
};
use crate::fastcv::perm_batch::{
    analytic_binary_permutation_jobs_ctx, analytic_multiclass_permutation_jobs_ctx,
    BatchStrategy, PermJob,
};
use crate::fastcv::ComputeContext;
use crate::linalg::{Mat, TilePolicy};
use crate::model::lda_binary::signed_codes;
use crate::store::{FactorStore, StoreStats};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, PoisonError};

/// Server configuration — the CLI's `fastcv serve` flags.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Request worker threads draining the queue (floored at 1). One
    /// worker preserves response order; more trade order for throughput.
    pub workers: usize,
    /// [`ComputeContext`] pool width per request (hat builds, fold prep,
    /// permutation batches). Wall-clock only — never moves a result.
    pub threads: usize,
    /// [`FactorStore`] resident-byte budget (`None` = unbounded).
    pub budget_bytes: Option<usize>,
    /// Spill directory for LRU demotion (and for the tile policy's
    /// out-of-core mode when `tile` is `Spill`).
    pub spill_dir: Option<PathBuf>,
    /// [`TilePolicy`] applied to every request's factor builds.
    pub tile: TilePolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            threads: 1,
            budget_bytes: None,
            spill_dir: None,
            tile: TilePolicy::Off,
        }
    }
}

/// The daemon: one [`FactorStore`] shared by every request, a coalescing
/// request queue, and the op handlers. Construct with [`Server::new`],
/// then drive it with [`Server::serve_stream`] (stdin/stdout or a socket
/// connection) or [`Server::process_batch`] (in-process: tests, benches).
pub struct Server {
    config: ServeConfig,
    store: FactorStore,
    /// Requests that rode along in another request's engine pass.
    coalesced: AtomicU64,
}

/// Parsed request envelope: the echoed `id`, the op, and the raw body for
/// op-specific fields.
struct Request {
    id: Json,
    op: String,
    body: Json,
}

impl Request {
    fn parse(line: &str) -> Result<Request> {
        let body = Json::parse(line).map_err(|e| anyhow!("bad request JSON: {e}"))?;
        let op = body
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request needs a string \"op\" field"))?
            .to_string();
        let id = body.get("id").cloned().unwrap_or(Json::Null);
        Ok(Request { id, op, body })
    }

    /// Merge key for queued `perm` requests (see the module docs); `None`
    /// for every other op and for inline-data perm requests.
    fn coalesce_key(&self) -> Option<String> {
        if self.op != "perm" {
            return None;
        }
        let syn = self.body.get("data")?.get("synthetic")?;
        let n = syn.get("n")?.as_usize()?;
        let p = syn.get("p")?.as_usize()?;
        let c = syn.get("c").and_then(Json::as_usize).unwrap_or(2);
        let dseed = syn.get("seed").and_then(Json::as_usize).unwrap_or(0);
        let k = self.body.get("folds")?.get("k")?.as_usize()?;
        let fseed = fold_seed(&self.body);
        let lambda = self.body.get("lambda").and_then(Json::as_f64).unwrap_or(1.0);
        let bias = truthy(&self.body, "bias_adjust");
        let backend = self.body.get("backend").and_then(Json::as_str).unwrap_or("auto");
        Some(format!(
            "n{n}|p{p}|c{c}|d{dseed}|k{k}|f{fseed}|l{:016x}|b{}|{backend}",
            lambda.to_bits(),
            u8::from(bias)
        ))
    }
}

/// Fold-RNG seed: `folds.seed`, defaulting to 1 (independent of the data
/// stream so equal fold specs reproduce across data sources).
fn fold_seed(body: &Json) -> u64 {
    body.get("folds")
        .and_then(|f| f.get("seed"))
        .and_then(Json::as_usize)
        .unwrap_or(1) as u64
}

fn truthy(body: &Json, key: &str) -> bool {
    matches!(body.get(key), Some(Json::Bool(true)))
}

/// Shared queue state between the reader (caller thread) and the workers.
struct Queue {
    jobs: Mutex<VecDeque<Request>>,
    ready: Condvar,
    open: AtomicBool,
}

impl Queue {
    fn new() -> Queue {
        Queue { jobs: Mutex::new(VecDeque::new()), ready: Condvar::new(), open: AtomicBool::new(true) }
    }

    fn push(&self, req: Request) {
        self.jobs.lock().unwrap_or_else(PoisonError::into_inner).push_back(req);
        self.ready.notify_one();
    }

    fn close(&self) {
        self.open.store(false, Ordering::SeqCst);
        self.ready.notify_all();
    }

    /// Block for the next request; drain queued requests sharing its
    /// coalesce key in the same critical section. `None` once the queue is
    /// closed and empty.
    fn next_job(&self) -> Option<(Request, Vec<Request>)> {
        let mut q = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(head) = q.pop_front() {
                let mut mates = Vec::new();
                if let Some(key) = head.coalesce_key() {
                    let mut rest = VecDeque::with_capacity(q.len());
                    while let Some(r) = q.pop_front() {
                        if r.coalesce_key().as_deref() == Some(key.as_str()) {
                            mates.push(r);
                        } else {
                            rest.push_back(r);
                        }
                    }
                    *q = rest;
                }
                return Some((head, mates));
            }
            if !self.open.load(Ordering::SeqCst) {
                return None;
            }
            q = self.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

impl Server {
    /// Build a server: the store takes the config's budget and (when a
    /// spill directory is configured) demotes LRU entries there.
    pub fn new(config: ServeConfig) -> Server {
        let store = match config.budget_bytes {
            Some(b) => FactorStore::with_budget(b),
            None => FactorStore::new(),
        };
        let store = match &config.spill_dir {
            Some(dir) => store.with_spill(dir.clone(), 256),
            None => store,
        };
        Server { config, store, coalesced: AtomicU64::new(0) }
    }

    /// The shared factor store (counters, tests, benches).
    pub fn store(&self) -> &FactorStore {
        &self.store
    }

    /// How many requests rode along in another request's coalesced engine
    /// pass so far (a group of M counts M − 1).
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::SeqCst)
    }

    /// Serve one NDJSON stream until EOF or a `shutdown` op, fanning
    /// requests over `config.workers` worker threads. Returns `true` if a
    /// `shutdown` op ended the stream (so a socket accept-loop knows to
    /// stop). Malformed lines get an immediate `ok:false` response and do
    /// not enter the queue.
    pub fn serve_stream<R: BufRead, W: Write + Send>(&self, reader: R, writer: W) -> Result<bool> {
        let queue = Queue::new();
        let out: Mutex<W> = Mutex::new(writer);
        let mut saw_shutdown = false;
        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..self.config.workers.max(1) {
                scope.spawn(|| self.worker_loop(&queue, &out));
            }
            let mut read_all = || -> Result<()> {
                for line in reader.lines() {
                    let line = line.context("reading request stream")?;
                    if line.trim().is_empty() {
                        continue;
                    }
                    match Request::parse(&line) {
                        Ok(req) => {
                            let stop = req.op == "shutdown";
                            queue.push(req);
                            if stop {
                                saw_shutdown = true;
                                break;
                            }
                        }
                        Err(e) => {
                            write_line(&out, &error_response(&Json::Null, &format!("{e:#}")));
                        }
                    }
                }
                Ok(())
            };
            // Close the queue even on a read error — otherwise the workers
            // (and this scope's join) would block forever on a torn stream.
            let read_result = read_all();
            queue.close();
            read_result
        })?;
        Ok(saw_shutdown)
    }

    /// Bind a Unix socket and serve connections **concurrently** — each
    /// accepted connection gets its own scoped handler thread running
    /// [`Server::serve_stream`], so a client that connects and idles never
    /// blocks the next client (they all share this server's store and
    /// queue semantics per connection). The loop runs until a `shutdown`
    /// op arrives on any connection; the handler then raises the shared
    /// shutdown flag, **severs every other live connection** (so handlers
    /// blocked reading an idle client observe EOF and exit instead of
    /// pinning the scope join forever), and self-connects to unblock the
    /// accept call, which re-checks the flag and stops. A connection that
    /// fails mid-stream (client vanished, torn socket) ends only that
    /// handler — the daemon keeps serving. A pre-existing socket file at
    /// `path` is replaced.
    pub fn serve_unix(&self, path: &std::path::Path) -> Result<()> {
        use std::os::unix::net::{UnixListener, UnixStream};
        std::fs::remove_file(path).ok();
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {}", path.display()))?;
        let shutdown = AtomicBool::new(false);
        // Live connections by accept id; the shutdown handler walks this
        // to cut idle readers loose.
        let conns: Mutex<BTreeMap<u64, UnixStream>> = Mutex::new(BTreeMap::new());
        let conn_seq = AtomicU64::new(0);
        let sock_path = path.to_path_buf();
        std::thread::scope(|scope| -> Result<()> {
            loop {
                let (conn, _) = listener.accept().context("accepting serve connection")?;
                let id = conn_seq.fetch_add(1, Ordering::SeqCst);
                // Register *before* checking the flag: either this insert
                // lands before the shutdown handler's sever pass (we get
                // severed) or after it (the lock hand-off makes the raised
                // flag visible below) — no connection can slip through
                // unsevered and unchecked.
                if let Ok(c) = conn.try_clone() {
                    conns.lock().unwrap_or_else(PoisonError::into_inner).insert(id, c);
                }
                if shutdown.load(Ordering::SeqCst) {
                    // The wake-up self-connection (or a late client during
                    // teardown): drop it and stop accepting.
                    conns.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
                    break;
                }
                let (shutdown, conns, sock_path) = (&shutdown, &conns, &sock_path);
                scope.spawn(move || {
                    // Ok(true) = this connection carried the shutdown op;
                    // errors are that client's problem, not the daemon's.
                    let carried_shutdown = match conn.try_clone() {
                        Ok(clone) => {
                            let reader = std::io::BufReader::new(clone);
                            matches!(self.serve_stream(reader, conn), Ok(true))
                        }
                        Err(_) => false,
                    };
                    conns.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
                    if carried_shutdown {
                        shutdown.store(true, Ordering::SeqCst);
                        // Sever every still-open connection so its handler
                        // unblocks and the scope can join…
                        let g = conns.lock().unwrap_or_else(PoisonError::into_inner);
                        for c in g.values() {
                            let _ = c.shutdown(std::net::Shutdown::Both);
                        }
                        drop(g);
                        // …and unblock the (possibly idle) accept loop.
                        let _ = UnixStream::connect(sock_path);
                    }
                });
            }
            Ok(())
        })?;
        std::fs::remove_file(path).ok();
        Ok(())
    }

    /// Process a batch of request lines in-process (tests, the
    /// `ablation_serve` bench, one-shot scripting): coalescing applies
    /// across the whole batch, and responses come back **in input order**
    /// (unlike multi-worker streams). Each line yields exactly one
    /// response line.
    pub fn process_batch(&self, lines: &[String]) -> Vec<String> {
        let parsed: Vec<Result<Request>> = lines.iter().map(|l| Request::parse(l)).collect();
        let mut responses: Vec<Option<Json>> = (0..lines.len()).map(|_| None).collect();
        for i in 0..parsed.len() {
            if responses[i].is_some() {
                continue;
            }
            match &parsed[i] {
                Err(e) => responses[i] = Some(error_response(&Json::Null, &format!("{e:#}"))),
                Ok(head) => match head.coalesce_key() {
                    None => responses[i] = Some(self.handle_single(head)),
                    Some(key) => {
                        let mut idx = vec![i];
                        for (j, later) in parsed.iter().enumerate().skip(i + 1) {
                            if responses[j].is_none()
                                && later
                                    .as_ref()
                                    .ok()
                                    .and_then(Request::coalesce_key)
                                    .as_deref()
                                    == Some(key.as_str())
                            {
                                idx.push(j);
                            }
                        }
                        let group: Vec<&Request> = idx
                            .iter()
                            .filter_map(|&j| parsed[j].as_ref().ok())
                            .collect();
                        let group_resps = self.handle_perm_group(&group);
                        for (&j, resp) in idx.iter().zip(group_resps) {
                            responses[j] = Some(resp);
                        }
                    }
                },
            }
        }
        responses
            .into_iter()
            .map(|r| r.unwrap_or_else(|| error_response(&Json::Null, "internal: unprocessed slot")).dump())
            .collect()
    }

    fn worker_loop<W: Write>(&self, queue: &Queue, out: &Mutex<W>) {
        while let Some((head, mates)) = queue.next_job() {
            if head.op == "shutdown" {
                write_line(out, &ok_response(&head.id, "shutdown", BTreeMap::new(), &self.store));
                queue.close();
                continue;
            }
            if mates.is_empty() && head.coalesce_key().is_none() {
                write_line(out, &self.handle_single(&head));
            } else {
                let mut group = vec![&head];
                group.extend(mates.iter());
                for resp in self.handle_perm_group(&group) {
                    write_line(out, &resp);
                }
            }
        }
    }

    /// One non-coalesced request → one response (never panics; errors
    /// become `ok:false` responses).
    fn handle_single(&self, req: &Request) -> Json {
        let result = match req.op.as_str() {
            "search" => self.op_search(req),
            "perm" => self
                .handle_perm_group(&[req])
                .pop()
                .ok_or_else(|| anyhow!("internal: empty perm group")),
            "sweep" => self.op_sweep(req),
            "stats" => self.op_stats(req),
            "shutdown" => Ok(ok_response(&req.id, "shutdown", BTreeMap::new(), &self.store)),
            other => Err(anyhow!("unknown op {other:?} (search|perm|sweep|stats|shutdown)")),
        };
        match result {
            Ok(resp) => resp,
            Err(e) => error_response(&req.id, &format!("{e:#}")),
        }
    }

    /// A group of perm requests sharing one coalesce key → one jobs-engine
    /// pass → one response per request, in group order. Also the single
    /// perm path (group of one).
    fn handle_perm_group(&self, group: &[&Request]) -> Vec<Json> {
        match self.run_perm_group(group) {
            Ok(resps) => resps,
            Err(e) => {
                let msg = format!("{e:#}");
                group.iter().map(|r| error_response(&r.id, &msg)).collect()
            }
        }
    }

    fn run_perm_group(&self, group: &[&Request]) -> Result<Vec<Json>> {
        let head = group.first().ok_or_else(|| anyhow!("internal: empty perm group"))?;
        let (ds, folds) = parse_dataset_and_folds(&head.body)?;
        let lambda = head.body.get("lambda").and_then(Json::as_f64).unwrap_or(1.0);
        let bias = truthy(&head.body, "bias_adjust");
        let batch = head.body.get("batch").and_then(Json::as_usize).unwrap_or(64);
        // Per-request anchors: the first draw of each request's RNG — the
        // exact draw a standalone engine run with that seed would make.
        let jobs: Vec<PermJob> = group
            .iter()
            .map(|r| {
                let seed = r.body.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
                let n_perm = r.body.get("n_perm").and_then(Json::as_usize).unwrap_or(100);
                PermJob { anchor: Rng::new(seed).next_u64(), n_perm }
            })
            .collect();
        let (ctx, resolved) =
            self.request_ctx(&head.body, ds.x.rows(), ds.x.cols(), usize::from(lambda > 0.0))?;
        let strategy = BatchStrategy::new(batch.max(1), self.config.threads.max(1));
        let results = if ds.n_classes == 2 {
            analytic_binary_permutation_jobs_ctx(
                &ds.x, &ds.labels, &folds, lambda, &jobs, bias, strategy, &ctx,
            )?
        } else {
            analytic_multiclass_permutation_jobs_ctx(
                &ds.x, &ds.labels, ds.n_classes, &folds, lambda, &jobs, strategy, &ctx,
            )?
        };
        self.coalesced.fetch_add(group.len() as u64 - 1, Ordering::SeqCst);
        Ok(group
            .iter()
            .zip(results)
            .map(|(req, res)| {
                let mut extra = BTreeMap::new();
                extra.insert("observed".into(), Json::Num(res.observed));
                extra.insert("p_value".into(), Json::Num(res.p_value));
                extra.insert("n_perm".into(), Json::Num(res.null.len() as f64));
                extra.insert("backend".into(), Json::Str(resolved.tag().to_string()));
                extra.insert("coalesced".into(), Json::Num(group.len() as f64));
                if truthy(&req.body, "return_null") {
                    extra.insert(
                        "null".into(),
                        Json::Arr(res.null.iter().map(|&v| Json::Num(v)).collect()),
                    );
                }
                ok_response(&req.id, "perm", extra, &self.store)
            })
            .collect())
    }

    fn op_search(&self, req: &Request) -> Result<Json> {
        let (ds, folds) = parse_dataset_and_folds(&req.body)?;
        let grid_vals: Vec<f64> = match req.body.get("grid").and_then(Json::as_arr) {
            Some(arr) => arr.iter().filter_map(Json::as_f64).collect(),
            None => vec![0.01, 0.1, 1.0, 10.0, 100.0],
        };
        if grid_vals.is_empty() {
            bail!("search: \"grid\" must hold at least one number");
        }
        let by = match req.body.get("by").and_then(Json::as_str).unwrap_or("accuracy") {
            "accuracy" => SelectBy::Accuracy,
            "auc" => SelectBy::Auc,
            "negmse" => SelectBy::NegMse,
            other => bail!("search: unknown \"by\" {other:?} (accuracy|auc|negmse)"),
        };
        let positives = grid_vals.iter().filter(|&&l| l > 0.0).count();
        let (ctx, resolved) =
            self.request_ctx(&req.body, ds.x.rows(), ds.x.cols(), positives)?;
        let search = if ds.n_classes == 2 {
            let y = signed_codes(&ds.labels);
            search_lambda_ctx(&ds.x, &y, &ds.labels, &folds, &grid_vals, by, &ctx)?
        } else {
            search_lambda_multiclass(&ds.x, &ds.labels, ds.n_classes, &folds, &grid_vals, &ctx)?
        };
        let mut extra = BTreeMap::new();
        extra.insert("lambda".into(), Json::Num(search.best_lambda()));
        extra.insert("score".into(), Json::Num(search.scores[search.best].score));
        extra.insert("backend".into(), Json::Str(resolved.tag().to_string()));
        extra.insert(
            "scores".into(),
            Json::Arr(
                search
                    .scores
                    .iter()
                    .map(|s| {
                        let mut o = BTreeMap::new();
                        o.insert("lambda".into(), Json::Num(s.lambda));
                        o.insert("score".into(), Json::Num(s.score));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Ok(ok_response(&req.id, "search", extra, &self.store))
    }

    fn op_sweep(&self, req: &Request) -> Result<Json> {
        let tag = req.body.get("exp").and_then(Json::as_str).unwrap_or("f3a").to_string();
        let exp = Experiment::from_tag(&tag)
            .ok_or_else(|| anyhow!("sweep: unknown experiment {tag:?} (f3a..f3d)"))?;
        let scale = match req.body.get("scale").and_then(Json::as_str).unwrap_or("tiny") {
            "paper" => SweepScale::paper(),
            "medium" => SweepScale::medium(),
            _ => SweepScale::tiny(),
        };
        let seed = req.body.get("seed").and_then(Json::as_usize).unwrap_or(2018) as u64;
        let workers = req.body.get("workers").and_then(Json::as_usize).unwrap_or(1);
        let backend_tag =
            req.body.get("backend").and_then(Json::as_str).unwrap_or("primal").to_string();
        let backend = GramBackend::from_tag(&backend_tag)
            .ok_or_else(|| anyhow!("sweep: unknown backend {backend_tag:?}"))?;
        let mut points = grid(exp, &scale);
        if let Some(limit) = req.body.get("limit").and_then(Json::as_usize) {
            points.truncate(limit);
        }
        for p in points.iter_mut() {
            p.backend = backend;
            p.threads = self.config.threads;
            p.tile = self.config.tile.clone();
            p.engine = PermEngine::Serial;
        }
        let sched = Scheduler::new(workers.max(1), seed, false);
        let clock = crate::util::monotonic_clock();
        let results = sched.run_clocked(&points, &clock, Some(&self.store));
        let report = SweepReport::new(results);
        let mut extra = BTreeMap::new();
        extra.insert("points".into(), Json::Num(points.len() as f64));
        extra.insert("tsv".into(), Json::Str(report.to_tsv()));
        Ok(ok_response(&req.id, "sweep", extra, &self.store))
    }

    fn op_stats(&self, req: &Request) -> Result<Json> {
        let s = self.store.stats();
        let mut extra = BTreeMap::new();
        extra.insert("hits".into(), Json::Num(s.hits as f64));
        extra.insert("misses".into(), Json::Num(s.misses as f64));
        extra.insert("evictions".into(), Json::Num(s.evictions as f64));
        extra.insert("demotions".into(), Json::Num(s.demotions as f64));
        extra.insert("entries".into(), Json::Num(s.entries as f64));
        extra.insert("resident_bytes".into(), Json::Num(s.resident_bytes as f64));
        extra.insert("coalesced".into(), Json::Num(self.coalesced() as f64));
        if let Some(b) = s.budget_bytes {
            extra.insert("budget_bytes".into(), Json::Num(b as f64));
        }
        Ok(ok_response(&req.id, "stats", extra, &self.store))
    }

    /// Build the per-request [`ComputeContext`]: the server's pool/tile/
    /// store plus the request's backend policy resolved for its shape —
    /// `auto` resolves through [`ComputeContext::resolve_for_grid`], so a
    /// spill-configured server steers Auto λ-grids to the fully
    /// streamable dual cache exactly like the CLI.
    fn request_ctx(
        &self,
        body: &Json,
        n: usize,
        p: usize,
        positives: usize,
    ) -> Result<(ComputeContext<'_>, GramBackend)> {
        let tag = body.get("backend").and_then(Json::as_str).unwrap_or("auto").to_string();
        let policy = GramBackend::from_tag(&tag)
            .ok_or_else(|| anyhow!("unknown backend {tag:?} (primal|dual|spectral|auto)"))?;
        let base = ComputeContext::with_threads(self.config.threads)
            .with_backend(policy)
            .with_tile_policy(self.config.tile.clone())
            .with_store(&self.store);
        let resolved = base.resolve_for_grid(n, p, positives.max(1));
        Ok((base.with_backend(resolved), resolved))
    }
}

/// Parse the request's dataset + folds: synthetic
/// (`{"data":{"synthetic":{n,p,c,seed}}}`) or inline
/// (`{"data":{"x":[[…]],"labels":[…]}}`), folds `{"k":K,"seed":S}` —
/// k-fold for binary, stratified for multi-class, drawn from
/// `Rng::new(folds.seed)` (default 1) so equal fold specs reproduce.
fn parse_dataset_and_folds(body: &Json) -> Result<(Dataset, Vec<Vec<usize>>)> {
    let data = body.get("data").ok_or_else(|| anyhow!("request needs a \"data\" object"))?;
    let ds = if let Some(syn) = data.get("synthetic") {
        let n = syn
            .get("n")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("synthetic data needs \"n\""))?;
        let p = syn
            .get("p")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("synthetic data needs \"p\""))?;
        let c = syn.get("c").and_then(Json::as_usize).unwrap_or(2);
        let seed = syn.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
        let spec = if c == 2 {
            SyntheticSpec::binary(n, p)
        } else {
            SyntheticSpec::multiclass(n, p, c)
        };
        generate(&spec, &mut Rng::new(seed))
    } else {
        let rows = data
            .get("x")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("data needs \"synthetic\" or inline \"x\" rows"))?;
        let labels: Vec<usize> = data
            .get("labels")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("inline data needs \"labels\""))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("labels must be non-negative integers")))
            .collect::<Result<_>>()?;
        let n = rows.len();
        anyhow::ensure!(n > 0 && n == labels.len(), "inline x/labels shape mismatch");
        let p = rows[0].as_arr().map_or(0, <[Json]>::len);
        anyhow::ensure!(p > 0, "inline x rows must be non-empty arrays");
        let mut x = Mat::zeros(n, p);
        for (i, row) in rows.iter().enumerate() {
            let vals = row.as_arr().ok_or_else(|| anyhow!("x row {i} is not an array"))?;
            anyhow::ensure!(vals.len() == p, "x row {i} has {} cols, expected {p}", vals.len());
            for (j, v) in vals.iter().enumerate() {
                x[(i, j)] = v.as_f64().ok_or_else(|| anyhow!("x[{i}][{j}] is not a number"))?;
            }
        }
        let c = data
            .get("c")
            .and_then(Json::as_usize)
            .unwrap_or_else(|| labels.iter().max().map_or(2, |&m| (m + 1).max(2)));
        Dataset { x, labels, n_classes: c }
    };
    let k = body
        .get("folds")
        .and_then(|f| f.get("k"))
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("request needs folds {{\"k\": K}}"))?;
    anyhow::ensure!(k >= 2 && k <= ds.n(), "folds k={k} out of range for n={}", ds.n());
    let mut frng = Rng::new(fold_seed(body));
    let folds = if ds.n_classes == 2 {
        kfold(ds.n(), k, &mut frng)
    } else {
        stratified_kfold(&ds.labels, k, &mut frng)
    };
    Ok((ds, folds))
}

/// `{"id":…, "ok":true, "op":…, …extra…, "cache":"h…/m…/e…/d…"}` — every
/// success response carries the store's counter tag (satellite: counters
/// surface in serve responses).
fn ok_response(id: &Json, op: &str, extra: BTreeMap<String, Json>, store: &FactorStore) -> Json {
    let mut obj = extra;
    obj.insert("id".into(), id.clone());
    obj.insert("ok".into(), Json::Bool(true));
    obj.insert("op".into(), Json::Str(op.to_string()));
    obj.insert("cache".into(), Json::Str(store.stats().tag()));
    Json::Obj(obj)
}

/// `{"id":…, "ok":false, "error":…}`.
fn error_response(id: &Json, msg: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("id".into(), id.clone());
    obj.insert("ok".into(), Json::Bool(false));
    obj.insert("error".into(), Json::Str(msg.to_string()));
    Json::Obj(obj)
}

fn write_line<W: Write>(out: &Mutex<W>, resp: &Json) {
    let mut w = out.lock().unwrap_or_else(PoisonError::into_inner);
    // A torn-down client is not a server error: drop the response.
    let _ = writeln!(w, "{}", resp.dump());
    let _ = w.flush();
}

/// A [`StoreStats`] counter snapshot rendered as the serve/TSV tag —
/// exported for the bench harness so it does not reach into the store.
pub fn stats_tag(stats: &StoreStats) -> String {
    stats.tag()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        s.to_string()
    }

    fn parse_ok(resp: &str) -> Json {
        let v = Json::parse(resp).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)), "{resp}");
        v
    }

    #[test]
    fn stats_shutdown_and_errors_roundtrip() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[
            line(r#"{"id":1,"op":"stats"}"#),
            line("not json"),
            line(r#"{"id":2,"op":"frobnicate"}"#),
            line(r#"{"id":3,"op":"shutdown"}"#),
        ]);
        assert_eq!(out.len(), 4);
        let stats = parse_ok(&out[0]);
        assert_eq!(stats.get("id").and_then(Json::as_f64), Some(1.0));
        assert_eq!(stats.get("hits").and_then(Json::as_f64), Some(0.0));
        assert!(stats.get("cache").and_then(Json::as_str).is_some());
        let bad = Json::parse(&out[1]).unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        let unknown = Json::parse(&out[2]).unwrap();
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
        assert!(unknown.get("error").and_then(Json::as_str).is_some());
        parse_ok(&out[3]);
    }

    #[test]
    fn perm_requests_coalesce_and_match_standalone_runs() {
        // Two queued perm requests on one key run as a single engine pass
        // and still answer exactly what standalone servers answer.
        let req_a = line(
            r#"{"id":"a","op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":100,"return_null":true}"#,
        );
        let req_b = line(
            r#"{"id":"b","op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":8,"seed":101,"return_null":true}"#,
        );
        let merged_server = Server::new(ServeConfig::default());
        let merged = merged_server.process_batch(&[req_a.clone(), req_b.clone()]);
        assert_eq!(merged_server.coalesced(), 1, "one rider in the merged pass");
        let solo_a = Server::new(ServeConfig::default()).process_batch(&[req_a])[0].clone();
        let solo_b = Server::new(ServeConfig::default()).process_batch(&[req_b])[0].clone();
        for (got, want) in [(&merged[0], &solo_a), (&merged[1], &solo_b)] {
            let g = parse_ok(got);
            let w = parse_ok(want);
            assert_eq!(g.get("observed"), w.get("observed"));
            assert_eq!(g.get("p_value"), w.get("p_value"));
            assert_eq!(g.get("null"), w.get("null"), "coalesced null must be bitwise equal");
        }
        let g0 = parse_ok(&merged[0]);
        assert_eq!(g0.get("coalesced").and_then(Json::as_f64), Some(2.0));
        // Requests on a *different* key must not join the group.
        let other = Server::new(ServeConfig::default());
        let out = other.process_batch(&[
            line(
                r#"{"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":5}},"folds":{"k":4},"lambda":1.0,"n_perm":4,"seed":1}"#,
            ),
            line(
                r#"{"op":"perm","data":{"synthetic":{"n":24,"p":12,"seed":6}},"folds":{"k":4},"lambda":1.0,"n_perm":4,"seed":1}"#,
            ),
        ]);
        assert_eq!(out.len(), 2);
        assert_eq!(other.coalesced(), 0, "different data seeds must not merge");
    }

    #[test]
    fn warm_store_serves_repeat_requests_from_cache() {
        let server = Server::new(ServeConfig::default());
        let req = line(
            r#"{"op":"perm","data":{"synthetic":{"n":20,"p":30,"seed":9}},"folds":{"k":4},"lambda":0.5,"n_perm":5,"seed":7}"#,
        );
        let cold = server.process_batch(&[req.clone()]);
        let cold_stats = server.store().stats();
        assert!(cold_stats.misses >= 1 && cold_stats.hits == 0, "{cold_stats:?}");
        let warm = server.process_batch(&[req]);
        let warm_stats = server.store().stats();
        assert!(warm_stats.hits >= 1, "repeat request must hit: {warm_stats:?}");
        // Warm answers are byte-identical to cold ones (modulo the cache
        // tag, which is allowed to move).
        let c = parse_ok(&cold[0]);
        let w = parse_ok(&warm[0]);
        assert_eq!(c.get("observed"), w.get("observed"));
        assert_eq!(c.get("p_value"), w.get("p_value"));
    }

    #[test]
    fn search_op_selects_from_grid_and_reports_backend() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[line(
            r#"{"op":"search","data":{"synthetic":{"n":30,"p":50,"seed":3}},"folds":{"k":5},"grid":[0.1,1.0,10.0]}"#,
        )]);
        let v = parse_ok(&out[0]);
        let lambda = v.get("lambda").and_then(Json::as_f64).unwrap();
        assert!([0.1, 1.0, 10.0].contains(&lambda), "winner {lambda} must come from the grid");
        // P > N with ≥2 positive candidates → Auto resolves to spectral.
        assert_eq!(v.get("backend").and_then(Json::as_str), Some("spectral"));
        assert_eq!(v.get("scores").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        // multi-class arm
        let out = server.process_batch(&[line(
            r#"{"op":"search","data":{"synthetic":{"n":40,"p":10,"c":4,"seed":3}},"folds":{"k":4},"grid":[0.5,5.0]}"#,
        )]);
        let v = parse_ok(&out[0]);
        assert!(v.get("lambda").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn inline_data_perm_runs_without_coalescing() {
        let server = Server::new(ServeConfig::default());
        let req = line(
            r#"{"op":"perm","data":{"x":[[0.1,1.2],[1.3,-0.4],[0.5,0.9],[-1.1,0.2],[0.7,1.1],[1.2,-0.8]],"labels":[0,1,0,1,0,1]},"folds":{"k":3},"lambda":1.0,"n_perm":4,"seed":2}"#,
        );
        let out = server.process_batch(&[req.clone(), req]);
        assert_eq!(out.len(), 2);
        let a = parse_ok(&out[0]);
        let b = parse_ok(&out[1]);
        assert_eq!(a.get("observed"), b.get("observed"));
        assert_eq!(server.coalesced(), 0, "inline data never merges");
        assert_eq!(a.get("coalesced").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn sweep_op_returns_tsv_and_shares_the_store() {
        let server = Server::new(ServeConfig::default());
        let out = server.process_batch(&[line(
            r#"{"op":"sweep","exp":"f3a","scale":"tiny","seed":2018,"limit":6}"#,
        )]);
        let v = parse_ok(&out[0]);
        assert_eq!(v.get("points").and_then(Json::as_usize), Some(6));
        let tsv = v.get("tsv").and_then(Json::as_str).unwrap();
        assert_eq!(tsv.lines().count(), 7, "header + 6 rows");
        assert!(tsv.starts_with("exp\t"), "{tsv}");
        // The first six tiny f3a points share one (n,p,rep) dataset across
        // fold counts → the scheduler's canonical-seed sharing must score
        // real store hits.
        let s = server.store().stats();
        assert!(s.hits >= 1, "sweep points sharing a dataset must share factors: {s:?}");
        // And the sweep is reproducible through a fresh server.
        let again = Server::new(ServeConfig::default()).process_batch(&[line(
            r#"{"op":"sweep","exp":"f3a","scale":"tiny","seed":2018,"limit":6}"#,
        )]);
        let v2 = parse_ok(&again[0]);
        let strip_timing = |t: &str| -> Vec<String> {
            t.lines()
                .map(|l| {
                    l.split('\t')
                        .enumerate()
                        .filter(|(i, _)| ![11, 12, 13, 14].contains(i))
                        .map(|(_, f)| f.to_string())
                        .collect::<Vec<_>>()
                        .join("\t")
                })
                .collect()
        };
        assert_eq!(
            strip_timing(tsv),
            strip_timing(v2.get("tsv").and_then(Json::as_str).unwrap()),
            "non-timing sweep columns must reproduce"
        );
    }

    #[test]
    fn serve_unix_overlapping_clients_are_served_concurrently() {
        // Client A connects first and goes idle; client B connects while A
        // is still open and expects an answer. Under the old sequential
        // accept loop B would block behind A forever — the read timeout
        // below turns that regression into a test failure instead of a
        // hang. A then carries the shutdown op that stops the daemon.
        use std::io::{BufRead as _, BufReader, Write as _};
        use std::os::unix::net::UnixStream;
        use std::time::Duration;
        let dir = std::env::temp_dir()
            .join(format!("fastcv_serve_unix_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("s.sock");
        let server = Server::new(ServeConfig::default());
        std::thread::scope(|scope| {
            let daemon = scope.spawn(|| server.serve_unix(&sock));
            let connect = || {
                for _ in 0..500 {
                    if let Ok(c) = UnixStream::connect(&sock) {
                        return c;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                panic!("serve_unix socket never came up");
            };
            let mut a = connect();
            let mut b = connect();
            b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            writeln!(b, r#"{{"id":"b","op":"stats"}}"#).unwrap();
            b.flush().unwrap();
            let mut b_reader = BufReader::new(b.try_clone().unwrap());
            let mut resp = String::new();
            b_reader.read_line(&mut resp).expect("B must be answered while A idles");
            let v = parse_ok(&resp);
            assert_eq!(v.get("id").and_then(Json::as_str), Some("b"));
            // A is still connected; now it shuts the daemon down. B stays
            // connected and idle the whole time — the daemon must sever
            // B's connection itself rather than wait on it, so the join
            // below completes while B is still open.
            a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            writeln!(a, r#"{{"id":"a","op":"shutdown"}}"#).unwrap();
            a.flush().unwrap();
            let mut a_reader = BufReader::new(a.try_clone().unwrap());
            let mut resp = String::new();
            a_reader.read_line(&mut resp).unwrap();
            parse_ok(&resp);
            daemon.join().unwrap().unwrap();
            assert!(!sock.exists(), "socket file must be removed on shutdown");
            // The severed idle client reads EOF, not a hang.
            let mut tail = String::new();
            assert_eq!(b_reader.read_line(&mut tail).unwrap(), 0, "B must see EOF");
            drop(a);
            drop(b);
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_stream_answers_every_request_and_stops_on_shutdown() {
        let server = Server::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let input = [
            r#"{"id":1,"op":"stats"}"#,
            r#"{"id":2,"op":"perm","data":{"synthetic":{"n":20,"p":8,"seed":4}},"folds":{"k":4},"lambda":1.0,"n_perm":3,"seed":11}"#,
            r#"{"id":3,"op":"perm","data":{"synthetic":{"n":20,"p":8,"seed":4}},"folds":{"k":4},"lambda":1.0,"n_perm":3,"seed":12}"#,
            r#"{"id":4,"op":"shutdown"}"#,
            r#"{"id":5,"op":"stats"}"#,
        ]
        .join("\n");
        let mut out: Vec<u8> = Vec::new();
        let shut = server
            .serve_stream(std::io::Cursor::new(input.into_bytes()), &mut out)
            .unwrap();
        assert!(shut, "shutdown op must be reported");
        let text = String::from_utf8(out).unwrap();
        let responses: Vec<Json> =
            text.lines().map(|l| Json::parse(l).unwrap()).collect();
        // Requests after shutdown are never read: exactly 4 responses.
        assert_eq!(responses.len(), 4, "{text}");
        let mut ids: Vec<f64> =
            responses.iter().filter_map(|r| r.get("id").and_then(Json::as_f64)).collect();
        ids.sort_by(f64::total_cmp);
        assert_eq!(ids, vec![1.0, 2.0, 3.0, 4.0]);
        for r in &responses {
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{text}");
        }
    }
}
