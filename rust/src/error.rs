//! The typed error taxonomy for the serve/store/spill boundaries.
//!
//! Inside the library, `anyhow` contexts remain the right tool — errors
//! are for humans reading a CLI message. At the *daemon boundary* they
//! are for machines: a client deciding whether to retry needs to tell an
//! `overloaded` rejection (retry after backoff) from a `bad_request`
//! (never retry) without parsing prose. [`FastCvError`] carries that
//! machine-readable `kind`; the serve layer attaches it to responses as a
//! `"kind"` field (plus `"field"` for `bad_request`), and
//! [`crate::runtime::serve_client`] keys its retry policy off it. See
//! `docs/ROBUSTNESS.md` for the full taxonomy and retry semantics.

/// A typed fault at the serve/store/spill boundary. Wrapped in
/// `anyhow::Error` on the way up (so every existing `Result` plumbing
/// works unchanged) and recovered by downcast at the response encoder.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FastCvError {
    /// The request was malformed: a field was missing, of the wrong type,
    /// or out of range. Never retryable — the same bytes will fail again.
    BadRequest {
        /// The offending field, echoed to the client.
        field: String,
        /// What was wrong with it.
        detail: String,
    },
    /// The request's deadline expired before a worker could run it.
    DeadlineExceeded {
        /// The configured per-request deadline in milliseconds.
        deadline_ms: u64,
    },
    /// The job queue was at capacity; the request was rejected at
    /// admission. Retryable after backoff — the daemon is up, just busy.
    Overloaded {
        /// The configured queue capacity.
        cap: usize,
    },
    /// A worker panicked while processing the request. The daemon
    /// survives (the panic is caught at the worker boundary); the request
    /// gets this instead of silence.
    WorkerPanic {
        /// The panic payload's message, when it was a string.
        detail: String,
    },
    /// On-disk state failed its checksum. The store recovers by evicting
    /// and rebuilding; this surfaces only when recovery itself fails.
    Corrupt {
        /// Which artifact, and how the checksum failed.
        detail: String,
    },
}

impl FastCvError {
    /// The machine-readable kind tag — the serve response's `"kind"`
    /// field and the retry policy's discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            FastCvError::BadRequest { .. } => "bad_request",
            FastCvError::DeadlineExceeded { .. } => "deadline_exceeded",
            FastCvError::Overloaded { .. } => "overloaded",
            FastCvError::WorkerPanic { .. } => "worker_panic",
            FastCvError::Corrupt { .. } => "corrupt",
        }
    }

    /// The offending field for `bad_request` (echoed in the response).
    pub fn field(&self) -> Option<&str> {
        match self {
            FastCvError::BadRequest { field, .. } => Some(field),
            _ => None,
        }
    }

    /// Is a verbatim retry of the same request safe *and* potentially
    /// useful? `overloaded` and `worker_panic` are transient daemon
    /// states; `bad_request` and `deadline_exceeded` will fail the same
    /// way again (the deadline is the client's own budget), and `corrupt`
    /// needs the store's rebuild, not a blind resend.
    pub fn is_retryable(&self) -> bool {
        matches!(self, FastCvError::Overloaded { .. } | FastCvError::WorkerPanic { .. })
    }

    /// Parse a kind tag back into a skeleton error (no payload) — the
    /// client side of the wire protocol, for keying retry policy off a
    /// response's `"kind"` field.
    pub fn from_kind(kind: &str) -> Option<FastCvError> {
        match kind {
            "bad_request" => {
                Some(FastCvError::BadRequest { field: String::new(), detail: String::new() })
            }
            "deadline_exceeded" => Some(FastCvError::DeadlineExceeded { deadline_ms: 0 }),
            "overloaded" => Some(FastCvError::Overloaded { cap: 0 }),
            "worker_panic" => Some(FastCvError::WorkerPanic { detail: String::new() }),
            "corrupt" => Some(FastCvError::Corrupt { detail: String::new() }),
            _ => None,
        }
    }
}

impl std::fmt::Display for FastCvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FastCvError::BadRequest { field, detail } => {
                write!(f, "bad request: field {field:?}: {detail}")
            }
            FastCvError::DeadlineExceeded { deadline_ms } => {
                write!(f, "deadline exceeded ({deadline_ms} ms)")
            }
            FastCvError::Overloaded { cap } => {
                write!(f, "overloaded: job queue at capacity ({cap})")
            }
            FastCvError::WorkerPanic { detail } => write!(f, "worker panicked: {detail}"),
            FastCvError::Corrupt { detail } => write!(f, "corrupt artifact: {detail}"),
        }
    }
}

impl std::error::Error for FastCvError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_round_trip_and_classify_retryability() {
        let errs = [
            FastCvError::BadRequest { field: "lambda".into(), detail: "not a number".into() },
            FastCvError::DeadlineExceeded { deadline_ms: 50 },
            FastCvError::Overloaded { cap: 4 },
            FastCvError::WorkerPanic { detail: "boom".into() },
            FastCvError::Corrupt { detail: "panel 3".into() },
        ];
        for e in &errs {
            let back = FastCvError::from_kind(e.kind()).expect(e.kind());
            assert_eq!(back.kind(), e.kind());
            assert_eq!(back.is_retryable(), e.is_retryable());
        }
        assert!(FastCvError::from_kind("nonsense").is_none());
        assert!(FastCvError::Overloaded { cap: 1 }.is_retryable());
        assert!(!FastCvError::BadRequest { field: "x".into(), detail: String::new() }
            .is_retryable());
        assert_eq!(
            FastCvError::BadRequest { field: "k".into(), detail: "missing".into() }.field(),
            Some("k")
        );
        assert_eq!(FastCvError::Overloaded { cap: 1 }.field(), None);
    }

    #[test]
    fn display_echoes_the_offending_field() {
        let e = FastCvError::BadRequest { field: "folds".into(), detail: "must be ≥ 2".into() };
        let msg = e.to_string();
        assert!(msg.contains("folds") && msg.contains("≥ 2"), "{msg}");
        // a downcast through anyhow recovers the typed value
        let any = anyhow::Error::from(e.clone());
        assert_eq!(any.downcast_ref::<FastCvError>(), Some(&e));
    }
}
