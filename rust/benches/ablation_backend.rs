//! Ablation: Gram backends for the analytic CV hat build.
//!
//! 1. **Backend grid** (always runs) — primal vs dual vs spectral across an
//!    N/P grid, timing one full analytic CV per backend, plus the λ-grid
//!    sweep contrast: per-candidate hat rebuild (primal) vs one spectral
//!    decomposition reused across the whole grid. Emits `BENCH_backend.json`
//!    (`$FASTCV_BENCH_OUT` or the working directory) for the perf
//!    trajectory. The headline rows: dual beats primal on the P ≫ N shapes
//!    and the spectral sweep beats the per-λ rebuild on an 8-point grid.
//! 2. **XLA artifact comparison** (skips cleanly without `make artifacts`)
//!    — native Rust engine vs AOT XLA artifact (PJRT) for the same graphs.
//!
//! Env: `FASTCV_BENCH_SCALE=tiny` for a fast smoke run (CI).
//! Run: `cargo bench --bench ablation_backend`

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::hat::{GramBackend, GramCache, HatMatrix};
use fastcv::fastcv::lambda_search::{default_grid, hat_for_lambda, search_lambda_backend, SelectBy};
use fastcv::fastcv::FoldCache;
use fastcv::runtime::hybrid::{analytic_cv, analytic_cv_batch, Engine};
use fastcv::runtime::XlaRuntime;
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::threadpool::ThreadPool;
use std::collections::BTreeMap;

fn main() {
    backend_grid_ablation();
    xla_ablation();
}

/// One analytic CV (hat build + fold solves) through a given backend.
fn run_cv(
    x: &fastcv::linalg::Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    lambda: f64,
    backend: GramBackend,
    pool: Option<&ThreadPool>,
) -> Vec<f64> {
    let hat = HatMatrix::build_with(x, lambda, backend, pool).unwrap();
    let cv = AnalyticBinaryCv::with_hat(hat, y);
    let cache = FoldCache::prepare(&cv.hat, folds, false).unwrap();
    cv.decision_values_cached(&cache)
}

fn backend_grid_ablation() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;
    let shapes: &[(usize, usize)] = if tiny {
        &[(40, 20), (24, 96), (20, 160)]
    } else {
        &[(200, 50), (150, 150), (100, 400), (60, 1200)]
    };
    let pool = ThreadPool::with_default_size(8);

    let mut table = Table::new(vec!["shape", "primal", "dual", "spectral", "dual/primal"])
        .with_title("Ablation: Gram backends, one analytic CV per backend".to_string());
    let mut grid_rows = Vec::new();
    for &(n, p) in shapes {
        let mut rng = Rng::new((n * 131 + p) as u64);
        let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(n, 10.min(n / 3), &mut rng);

        let t_primal =
            bench.run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Primal, None)).median;
        let t_dual = bench
            .run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Dual, Some(&pool)))
            .median;
        let t_spectral =
            bench.run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Spectral, Some(&pool))).median;

        // agreement check rides along so the JSON records correctness too
        let dv_p = run_cv(&ds.x, &y, &folds, lambda, GramBackend::Primal, None);
        let dv_d = run_cv(&ds.x, &y, &folds, lambda, GramBackend::Dual, None);
        let max_diff = dv_p
            .iter()
            .zip(&dv_d)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));

        let speedup = t_primal / t_dual;
        table.row(vec![
            format!("N={n} P={p}"),
            fdur(t_primal),
            fdur(t_dual),
            fdur(t_spectral),
            format!("{speedup:.2}x"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), Json::Num(n as f64));
        row.insert("p".to_string(), Json::Num(p as f64));
        row.insert("seconds_primal".to_string(), Json::Num(t_primal));
        row.insert("seconds_dual".to_string(), Json::Num(t_dual));
        row.insert("seconds_spectral".to_string(), Json::Num(t_spectral));
        row.insert("speedup_dual_vs_primal".to_string(), Json::Num(speedup));
        row.insert("max_abs_dv_diff_dual".to_string(), Json::Num(max_diff));
        grid_rows.push(Json::Obj(row));
    }
    println!("{}", table.render());

    // λ-grid sweep: per-candidate primal rebuild vs one spectral
    // decomposition shared across the whole grid (≥ 8 points).
    let (n, p, k, g) = if tiny { (24, 96, 4, 8) } else { (80, 800, 8, 12) };
    let mut rng = Rng::new(2024);
    let mut spec = SyntheticSpec::binary(n, p);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);
    let grid = default_grid(g);
    // True rebuild baseline: a from-scratch primal hat per candidate via
    // `hat_for_lambda` — the pre-GramCache cost (`search_lambda_backend`
    // with Primal already shares the gram across the grid, which is a
    // different, cheaper arm measured separately below).
    let rebuild_sweep = || {
        let mut best = (f64::NEG_INFINITY, grid[0]);
        for &l in &grid {
            let hat = hat_for_lambda(&ds.x, l).unwrap();
            let cv = AnalyticBinaryCv::with_hat(hat, &y);
            let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
            let acc =
                fastcv::cv::metrics::accuracy_signed(&cv.decision_values_cached(&cache), &y);
            if acc > best.0 {
                best = (acc, l);
            }
        }
        best
    };
    let t_rebuild = bench.run(&rebuild_sweep).median;
    let t_primal_shared = bench
        .run(|| {
            search_lambda_backend(
                &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Primal,
            )
            .unwrap()
        })
        .median;
    let t_spectral_sweep = bench
        .run(|| {
            search_lambda_backend(
                &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Spectral,
            )
            .unwrap()
        })
        .median;
    // all three must pick the same winner — record it
    let (_, rebuild_lambda) = rebuild_sweep();
    let w_spectral = search_lambda_backend(
        &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Spectral,
    )
    .unwrap();
    let sweep_speedup = t_rebuild / t_spectral_sweep;
    let mut sweep_table = Table::new(vec!["method", "time", "speedup"]).with_title(format!(
        "λ-grid sweep: N={n} P={p} K={k}, {g} candidates"
    ));
    sweep_table.row(vec![
        "primal rebuild per λ (hat_for_lambda)".into(),
        fdur(t_rebuild),
        "1.00x ref".into(),
    ]);
    sweep_table.row(vec![
        "primal, shared gram (GramCache)".into(),
        fdur(t_primal_shared),
        format!("{:.1}x", t_rebuild / t_primal_shared),
    ]);
    sweep_table.row(vec![
        "spectral, one decomposition".into(),
        fdur(t_spectral_sweep),
        format!("{sweep_speedup:.1}x"),
    ]);
    println!("{}", sweep_table.render());
    println!(
        "winner agreement: rebuild λ={} / spectral λ={}",
        rebuild_lambda,
        w_spectral.best_lambda()
    );
    // spectral GramCache reuse directly (no scoring): per-λ hat cost
    let cache = GramCache::build(&ds.x, GramBackend::Spectral, Some(&pool));
    let t_per_lambda = bench.run(|| cache.hat(1.0).unwrap()).median;

    let mut sweep = BTreeMap::new();
    for (key, value) in [("n", n), ("p", p), ("k", k), ("grid_points", g)] {
        sweep.insert(key.to_string(), Json::Num(value as f64));
    }
    sweep.insert("seconds_primal_rebuild".to_string(), Json::Num(t_rebuild));
    sweep.insert("seconds_primal_shared_gram".to_string(), Json::Num(t_primal_shared));
    sweep.insert("seconds_spectral_reuse".to_string(), Json::Num(t_spectral_sweep));
    sweep.insert("speedup_spectral_vs_rebuild".to_string(), Json::Num(sweep_speedup));
    sweep.insert("seconds_spectral_hat_per_lambda".to_string(), Json::Num(t_per_lambda));
    sweep.insert("same_winner".to_string(), Json::Bool(rebuild_lambda == w_spectral.best_lambda()));

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("gram_backends".to_string()));
    doc.insert("lambda".to_string(), Json::Num(lambda));
    doc.insert("grid".to_string(), Json::Arr(grid_rows));
    doc.insert("lambda_grid_sweep".to_string(), Json::Obj(sweep));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_backend.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Native Rust engine vs AOT XLA artifact (PJRT) for the same analytic CV —
/// quantifies what the compiled L1/L2 stack buys (or costs) on this CPU
/// target. Needs `make artifacts`; returns cleanly when none are present.
fn xla_ablation() {
    let rt = match XlaRuntime::load_default() {
        Ok(rt) if !rt.registry().is_empty() => rt,
        _ => {
            println!("no artifacts — run `make artifacts`; skipping XLA ablation.");
            return;
        }
    };
    let bench = Bench::quick();
    let mut table = Table::new(vec!["graph", "native", "xla (pjrt)", "xla/native"])
        .with_title("Ablation: native Rust vs AOT XLA artifact".to_string());

    // N=100, P=380, K=10 (the EEG-scale artifact) single CV
    let (n, p, k, b) = (100usize, 380usize, 10usize, 20usize);
    let mut rng = Rng::new(8);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);

    // warm the executable cache so compile time isn't measured
    let (_, engine) = analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap();
    if engine != Engine::Xla {
        println!("artifact for (n={n},p={p},k={k}) missing; skipping");
        return;
    }
    let t_native = bench.run(|| analytic_cv(None, &ds.x, &y, &folds, 1.0).unwrap()).median;
    let t_xla = bench.run(|| analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv n={n} p={p} k={k}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    // batched permutations
    let mut perms = Vec::with_capacity(b);
    for _ in 0..b {
        let perm = rng.permutation(n);
        perms.push(perm.iter().map(|&i| y[i]).collect::<Vec<f64>>());
    }
    let _ = analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap();
    let t_native =
        bench.run(|| analytic_cv_batch(None, &ds.x, &perms, &folds, 1.0).unwrap()).median;
    let t_xla =
        bench.run(|| analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv_batch b={b}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    println!("{}", table.render());
}
