//! Ablation: Gram backends for the analytic CV hat build.
//!
//! 1. **Backend grid** (always runs) — primal vs dual vs spectral across an
//!    N/P grid, timing one full analytic CV per backend, plus the λ-grid
//!    sweep contrast: per-candidate hat rebuild (primal) vs one spectral
//!    decomposition reused across the whole grid. Emits `BENCH_backend.json`
//!    (`$FASTCV_BENCH_OUT` or the working directory) for the perf
//!    trajectory. The headline rows: dual beats primal on the P ≫ N shapes
//!    and the spectral sweep beats the per-λ rebuild on an 8-point grid.
//! 2. **Pooled Gram builds** — serial vs ComputeContext-pooled `K_c` GEMM
//!    (dual/spectral) and `syrk_t` primal gram on a wide shape; the pooled
//!    builds are bit-identical, so the contrast is pure wall-clock.
//! 3. **Multi-class λ grid** — `search_lambda_multiclass` with one shared
//!    spectral decomposition vs a from-scratch multi-class rebuild per
//!    candidate, on a wide shape.
//! 4. **XLA artifact comparison** (skips cleanly without `make artifacts`)
//!    — native Rust engine vs AOT XLA artifact (PJRT) for the same graphs.
//!
//! Env: `FASTCV_BENCH_SCALE=tiny` for a fast smoke run (CI).
//! Run: `cargo bench --bench ablation_backend`

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::hat::{GramBackend, GramCache, HatMatrix};
use fastcv::fastcv::lambda_search::{default_grid, hat_for_lambda, search_lambda_backend, SelectBy};
use fastcv::fastcv::FoldCache;
use fastcv::runtime::hybrid::{analytic_cv, analytic_cv_batch, Engine};
use fastcv::runtime::XlaRuntime;
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::threadpool::ThreadPool;
use std::collections::BTreeMap;

fn main() {
    backend_grid_ablation();
    pooled_build_ablation();
    multiclass_grid_ablation();
    xla_ablation();
}

/// One analytic CV (hat build + fold solves) through a given backend.
fn run_cv(
    x: &fastcv::linalg::Mat,
    y: &[f64],
    folds: &[Vec<usize>],
    lambda: f64,
    backend: GramBackend,
    pool: Option<&ThreadPool>,
) -> Vec<f64> {
    let hat = HatMatrix::build_with(x, lambda, backend, pool).unwrap();
    let cv = AnalyticBinaryCv::with_hat(hat, y);
    let cache = FoldCache::prepare(&cv.hat, folds, false).unwrap();
    cv.decision_values_cached(&cache)
}

fn backend_grid_ablation() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;
    let shapes: &[(usize, usize)] = if tiny {
        &[(40, 20), (24, 96), (20, 160)]
    } else {
        &[(200, 50), (150, 150), (100, 400), (60, 1200)]
    };
    let pool = ThreadPool::with_default_size(8);

    let mut table = Table::new(vec!["shape", "primal", "dual", "spectral", "dual/primal"])
        .with_title("Ablation: Gram backends, one analytic CV per backend".to_string());
    let mut grid_rows = Vec::new();
    for &(n, p) in shapes {
        let mut rng = Rng::new((n * 131 + p) as u64);
        let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
        let y = ds.y_signed();
        let folds = kfold(n, 10.min(n / 3), &mut rng);

        let t_primal =
            bench.run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Primal, None)).median;
        let t_dual = bench
            .run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Dual, Some(&pool)))
            .median;
        let t_spectral =
            bench.run(|| run_cv(&ds.x, &y, &folds, lambda, GramBackend::Spectral, Some(&pool))).median;

        // agreement check rides along so the JSON records correctness too
        let dv_p = run_cv(&ds.x, &y, &folds, lambda, GramBackend::Primal, None);
        let dv_d = run_cv(&ds.x, &y, &folds, lambda, GramBackend::Dual, None);
        let max_diff = dv_p
            .iter()
            .zip(&dv_d)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));

        let speedup = t_primal / t_dual;
        table.row(vec![
            format!("N={n} P={p}"),
            fdur(t_primal),
            fdur(t_dual),
            fdur(t_spectral),
            format!("{speedup:.2}x"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), Json::Num(n as f64));
        row.insert("p".to_string(), Json::Num(p as f64));
        row.insert("seconds_primal".to_string(), Json::Num(t_primal));
        row.insert("seconds_dual".to_string(), Json::Num(t_dual));
        row.insert("seconds_spectral".to_string(), Json::Num(t_spectral));
        row.insert("speedup_dual_vs_primal".to_string(), Json::Num(speedup));
        row.insert("max_abs_dv_diff_dual".to_string(), Json::Num(max_diff));
        grid_rows.push(Json::Obj(row));
    }
    println!("{}", table.render());

    // λ-grid sweep: per-candidate primal rebuild vs one spectral
    // decomposition shared across the whole grid (≥ 8 points).
    let (n, p, k, g) = if tiny { (24, 96, 4, 8) } else { (80, 800, 8, 12) };
    let mut rng = Rng::new(2024);
    let mut spec = SyntheticSpec::binary(n, p);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);
    let grid = default_grid(g);
    // True rebuild baseline: a from-scratch primal hat per candidate via
    // `hat_for_lambda` — the pre-GramCache cost (`search_lambda_backend`
    // with Primal already shares the gram across the grid, which is a
    // different, cheaper arm measured separately below).
    let rebuild_sweep = || {
        let mut best = (f64::NEG_INFINITY, grid[0]);
        for &l in &grid {
            let hat = hat_for_lambda(&ds.x, l).unwrap();
            let cv = AnalyticBinaryCv::with_hat(hat, &y);
            let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
            let acc =
                fastcv::cv::metrics::accuracy_signed(&cv.decision_values_cached(&cache), &y);
            if acc > best.0 {
                best = (acc, l);
            }
        }
        best
    };
    let t_rebuild = bench.run(&rebuild_sweep).median;
    let t_primal_shared = bench
        .run(|| {
            search_lambda_backend(
                &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Primal,
            )
            .unwrap()
        })
        .median;
    let t_spectral_sweep = bench
        .run(|| {
            search_lambda_backend(
                &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Spectral,
            )
            .unwrap()
        })
        .median;
    // all three must pick the same winner — record it
    let (_, rebuild_lambda) = rebuild_sweep();
    let w_spectral = search_lambda_backend(
        &ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy, GramBackend::Spectral,
    )
    .unwrap();
    let sweep_speedup = t_rebuild / t_spectral_sweep;
    let mut sweep_table = Table::new(vec!["method", "time", "speedup"]).with_title(format!(
        "λ-grid sweep: N={n} P={p} K={k}, {g} candidates"
    ));
    sweep_table.row(vec![
        "primal rebuild per λ (hat_for_lambda)".into(),
        fdur(t_rebuild),
        "1.00x ref".into(),
    ]);
    sweep_table.row(vec![
        "primal, shared gram (GramCache)".into(),
        fdur(t_primal_shared),
        format!("{:.1}x", t_rebuild / t_primal_shared),
    ]);
    sweep_table.row(vec![
        "spectral, one decomposition".into(),
        fdur(t_spectral_sweep),
        format!("{sweep_speedup:.1}x"),
    ]);
    println!("{}", sweep_table.render());
    println!(
        "winner agreement: rebuild λ={} / spectral λ={}",
        rebuild_lambda,
        w_spectral.best_lambda()
    );
    // spectral GramCache reuse directly (no scoring): per-λ hat cost
    let cache = GramCache::build(&ds.x, GramBackend::Spectral, Some(&pool));
    let t_per_lambda = bench.run(|| cache.hat(1.0).unwrap()).median;

    let mut sweep = BTreeMap::new();
    for (key, value) in [("n", n), ("p", p), ("k", k), ("grid_points", g)] {
        sweep.insert(key.to_string(), Json::Num(value as f64));
    }
    sweep.insert("seconds_primal_rebuild".to_string(), Json::Num(t_rebuild));
    sweep.insert("seconds_primal_shared_gram".to_string(), Json::Num(t_primal_shared));
    sweep.insert("seconds_spectral_reuse".to_string(), Json::Num(t_spectral_sweep));
    sweep.insert("speedup_spectral_vs_rebuild".to_string(), Json::Num(sweep_speedup));
    sweep.insert("seconds_spectral_hat_per_lambda".to_string(), Json::Num(t_per_lambda));
    sweep.insert("same_winner".to_string(), Json::Bool(rebuild_lambda == w_spectral.best_lambda()));

    merge_into_bench_json(vec![
        ("bench", Json::Str("gram_backends".to_string())),
        ("lambda", Json::Num(lambda)),
        ("grid", Json::Arr(grid_rows)),
        ("lambda_grid_sweep", Json::Obj(sweep)),
    ]);
}

/// Serial vs pooled λ-free Gram builds on a wide (P ≫ N) shape: the
/// dual/spectral `K_c = X_cX_cᵀ` GEMM (`matmul_pool`) and the primal
/// `G₀ = X̃ᵀX̃` syrk (`syrk_t_pool`). Pooled builds are bit-identical to
/// serial (asserted below), so any speedup is free. Appends to the
/// `pooled_builds` section of `BENCH_backend.json`.
fn pooled_build_ablation() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let (n, p) = if tiny { (24usize, 160usize) } else { (100, 1600) };
    let mut rng = Rng::new(77);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let pool = ThreadPool::with_default_size(8);

    let t_kc_serial =
        bench.run(|| GramCache::build(&ds.x, GramBackend::Dual, None)).median;
    let t_kc_pool =
        bench.run(|| GramCache::build(&ds.x, GramBackend::Dual, Some(&pool))).median;
    let t_syrk_serial =
        bench.run(|| GramCache::build(&ds.x, GramBackend::Primal, None)).median;
    let t_syrk_pool =
        bench.run(|| GramCache::build(&ds.x, GramBackend::Primal, Some(&pool))).median;

    // bitwise identity rides along so the JSON records correctness too
    let identical = {
        let a = GramCache::build(&ds.x, GramBackend::Primal, None);
        let b = GramCache::build(&ds.x, GramBackend::Primal, Some(&pool));
        let (GramCache::Primal { g0: ga, .. }, GramCache::Primal { g0: gb, .. }) = (&a, &b)
        else {
            unreachable!()
        };
        ga.as_slice() == gb.as_slice()
    };

    let mut table = Table::new(vec!["build", "serial", "pooled", "speedup"])
        .with_title(format!("Pooled λ-free Gram builds, N={n} P={p}, {} workers", pool.size()));
    table.row(vec![
        "K_c = X_cX_cᵀ (dual/spectral)".into(),
        fdur(t_kc_serial),
        fdur(t_kc_pool),
        format!("{:.2}x", t_kc_serial / t_kc_pool),
    ]);
    table.row(vec![
        "G₀ = X̃ᵀX̃ (primal syrk_t)".into(),
        fdur(t_syrk_serial),
        fdur(t_syrk_pool),
        format!("{:.2}x", t_syrk_serial / t_syrk_pool),
    ]);
    println!("{}", table.render());
    println!("pooled primal gram bitwise identical to serial: {identical}");

    let mut doc = BTreeMap::new();
    doc.insert("n".to_string(), Json::Num(n as f64));
    doc.insert("p".to_string(), Json::Num(p as f64));
    doc.insert("workers".to_string(), Json::Num(pool.size() as f64));
    doc.insert("seconds_kc_serial".to_string(), Json::Num(t_kc_serial));
    doc.insert("seconds_kc_pool".to_string(), Json::Num(t_kc_pool));
    doc.insert("seconds_syrk_serial".to_string(), Json::Num(t_syrk_serial));
    doc.insert("seconds_syrk_pool".to_string(), Json::Num(t_syrk_pool));
    doc.insert("speedup_kc".to_string(), Json::Num(t_kc_serial / t_kc_pool));
    doc.insert("speedup_syrk".to_string(), Json::Num(t_syrk_serial / t_syrk_pool));
    doc.insert("bitwise_identical".to_string(), Json::Bool(identical));
    merge_into_bench_json(vec![("pooled_builds", Json::Obj(doc))]);
}

/// Multi-class λ grid on a wide shape: one shared spectral decomposition
/// (`search_lambda_multiclass`) vs a from-scratch multi-class rebuild per
/// candidate — the multi-class analogue of the binary sweep contrast above.
fn multiclass_grid_ablation() {
    use fastcv::fastcv::lambda_search::search_lambda_multiclass;
    use fastcv::fastcv::multiclass::AnalyticMulticlassCv;
    use fastcv::fastcv::ComputeContext;

    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let (n, p, c, g) = if tiny { (24usize, 96usize, 3usize, 6usize) } else { (60, 900, 5, 10) };
    let mut rng = Rng::new(4242);
    let spec = SyntheticSpec::multiclass(n, p, c);
    let ds = generate(&spec, &mut rng);
    let folds = fastcv::cv::folds::stratified_kfold(&ds.labels, 4, &mut rng);
    let grid = default_grid(g);

    // Per-candidate rebuild through the historical primal fit.
    let rebuild = || {
        let mut best = (f64::NEG_INFINITY, grid[0]);
        for &l in &grid {
            let cv = AnalyticMulticlassCv::fit(&ds.x, &ds.labels, c, l).unwrap();
            let pred = cv.predict(&folds).unwrap();
            let acc = fastcv::cv::metrics::accuracy_labels(&pred, &ds.labels);
            if acc > best.0 {
                best = (acc, l);
            }
        }
        best
    };
    let t_rebuild = bench.run(&rebuild).median;
    // Serial context on purpose: both arms single-threaded, so the speedup
    // isolates the one-shared-decomposition reuse (pool fan-out gains are
    // measured separately in the pooled_builds section).
    let ctx = ComputeContext::serial().with_backend(GramBackend::Spectral);
    let t_shared = bench
        .run(|| search_lambda_multiclass(&ds.x, &ds.labels, c, &folds, &grid, &ctx).unwrap())
        .median;
    let (_, lambda_rebuild) = rebuild();
    let shared = search_lambda_multiclass(&ds.x, &ds.labels, c, &folds, &grid, &ctx).unwrap();
    let speedup = t_rebuild / t_shared;

    let mut table = Table::new(vec!["method", "time", "speedup"]).with_title(format!(
        "multi-class λ grid: N={n} P={p} C={c}, {g} candidates (both arms serial)"
    ));
    table.row(vec!["primal rebuild per λ".into(), fdur(t_rebuild), "1.00x ref".into()]);
    table.row(vec![
        "spectral, one decomposition".into(),
        fdur(t_shared),
        format!("{speedup:.1}x"),
    ]);
    println!("{}", table.render());
    println!(
        "winner agreement: rebuild λ={lambda_rebuild} / shared λ={}",
        shared.best_lambda()
    );

    let mut doc = BTreeMap::new();
    for (key, value) in [("n", n), ("p", p), ("c", c), ("grid_points", g)] {
        doc.insert(key.to_string(), Json::Num(value as f64));
    }
    doc.insert("seconds_rebuild_per_lambda".to_string(), Json::Num(t_rebuild));
    doc.insert("seconds_spectral_shared".to_string(), Json::Num(t_shared));
    doc.insert("speedup_shared_vs_rebuild".to_string(), Json::Num(speedup));
    doc.insert(
        "same_winner".to_string(),
        Json::Bool(lambda_rebuild == shared.best_lambda()),
    );
    merge_into_bench_json(vec![("multiclass_lambda_grid", Json::Obj(doc))]);
}

/// Merge sections into `BENCH_backend.json`: read-modify-write, so every
/// ablation attaches its keys without clobbering the others regardless of
/// run order (and each works standalone).
fn merge_into_bench_json(entries: Vec<(&str, Json)>) {
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_backend.json");
    let mut doc = match std::fs::read_to_string(&path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(Json::Obj(map)) => map,
        _ => BTreeMap::new(),
    };
    let keys: Vec<String> = entries.iter().map(|(k, _)| k.to_string()).collect();
    for (key, value) in entries {
        doc.insert(key.to_string(), value);
    }
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("updated {path} [{}]", keys.join(", ")),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Native Rust engine vs AOT XLA artifact (PJRT) for the same analytic CV —
/// quantifies what the compiled L1/L2 stack buys (or costs) on this CPU
/// target. Needs `make artifacts`; returns cleanly when none are present.
fn xla_ablation() {
    let rt = match XlaRuntime::load_default() {
        Ok(rt) if !rt.registry().is_empty() => rt,
        _ => {
            println!("no artifacts — run `make artifacts`; skipping XLA ablation.");
            return;
        }
    };
    let bench = Bench::quick();
    let mut table = Table::new(vec!["graph", "native", "xla (pjrt)", "xla/native"])
        .with_title("Ablation: native Rust vs AOT XLA artifact".to_string());

    // N=100, P=380, K=10 (the EEG-scale artifact) single CV
    let (n, p, k, b) = (100usize, 380usize, 10usize, 20usize);
    let mut rng = Rng::new(8);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);

    // warm the executable cache so compile time isn't measured
    let (_, engine) = analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap();
    if engine != Engine::Xla {
        println!("artifact for (n={n},p={p},k={k}) missing; skipping");
        return;
    }
    let t_native = bench.run(|| analytic_cv(None, &ds.x, &y, &folds, 1.0).unwrap()).median;
    let t_xla = bench.run(|| analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv n={n} p={p} k={k}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    // batched permutations
    let mut perms = Vec::with_capacity(b);
    for _ in 0..b {
        let perm = rng.permutation(n);
        perms.push(perm.iter().map(|&i| y[i]).collect::<Vec<f64>>());
    }
    let _ = analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap();
    let t_native =
        bench.run(|| analytic_cv_batch(None, &ds.x, &perms, &folds, 1.0).unwrap()).median;
    let t_xla =
        bench.run(|| analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv_batch b={b}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    println!("{}", table.render());
}
