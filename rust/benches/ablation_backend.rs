//! Ablation: native Rust engine vs AOT XLA artifact (PJRT) for the same
//! analytic CV — quantifies what the compiled L1/L2 stack buys (or costs)
//! on this CPU target, for the single-response and batched-permutation
//! graphs.
//!
//! Needs `make artifacts`; exits cleanly when none are present.
//! Run: `cargo bench --bench ablation_backend`

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::runtime::hybrid::{analytic_cv, analytic_cv_batch, Engine};
use fastcv::runtime::XlaRuntime;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};

fn main() {
    let rt = match XlaRuntime::load_default() {
        Ok(rt) if !rt.registry().is_empty() => rt,
        _ => {
            println!("no artifacts — run `make artifacts`; skipping backend ablation.");
            return;
        }
    };
    let bench = Bench::quick();
    let mut table = Table::new(vec!["graph", "native", "xla (pjrt)", "xla/native"])
        .with_title("Ablation: native Rust vs AOT XLA artifact".to_string());

    // N=100, P=380, K=10 (the EEG-scale artifact) single CV
    let (n, p, k, b) = (100usize, 380usize, 10usize, 20usize);
    let mut rng = Rng::new(8);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);

    // warm the executable cache so compile time isn't measured
    let (_, engine) = analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap();
    if engine != Engine::Xla {
        println!("artifact for (n={n},p={p},k={k}) missing; skipping");
        return;
    }
    let t_native = bench.run(|| analytic_cv(None, &ds.x, &y, &folds, 1.0).unwrap()).median;
    let t_xla = bench.run(|| analytic_cv(Some(&rt), &ds.x, &y, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv n={n} p={p} k={k}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    // batched permutations
    let mut perms = Vec::with_capacity(b);
    for _ in 0..b {
        let perm = rng.permutation(n);
        perms.push(perm.iter().map(|&i| y[i]).collect::<Vec<f64>>());
    }
    let _ = analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap();
    let t_native =
        bench.run(|| analytic_cv_batch(None, &ds.x, &perms, &folds, 1.0).unwrap()).median;
    let t_xla =
        bench.run(|| analytic_cv_batch(Some(&rt), &ds.x, &perms, &folds, 1.0).unwrap()).median;
    table.row(vec![
        format!("analytic_cv_batch b={b}"),
        fdur(t_native),
        fdur(t_xla),
        format!("{:.2}x", t_xla / t_native),
    ]);

    println!("{}", table.render());
}
