//! Fig. 3a — binary LDA cross-validation: relative efficiency
//! (log10 t_standard/t_analytic) over a log grid of feature counts, for
//! N ∈ {small, large} and folds ∈ {5, 10, 20, LOO}.
//!
//! Scale via env: FASTCV_BENCH_SCALE=tiny|medium|paper (default medium).
//! Run: `cargo bench --bench fig3_binary_cv`

use fastcv::coordinator::sweep::{grid, Experiment, SweepScale};
use fastcv::coordinator::{Scheduler, SweepReport};

fn scale_from_env() -> SweepScale {
    match std::env::var("FASTCV_BENCH_SCALE").as_deref() {
        Ok("tiny") => SweepScale::tiny(),
        Ok("paper") => SweepScale::paper(),
        _ => SweepScale::medium(),
    }
}

fn main() {
    let scale = scale_from_env();
    let points = grid(Experiment::BinaryCv, &scale);
    eprintln!("fig3a: {} sweep points", points.len());
    let sched = Scheduler::new(0, 2018, true);
    let report = SweepReport::new(sched.run(&points));
    println!("{}", report.render("Fig. 3a — binary LDA cross-validation"));
    // Paper shape checks (soft, printed not asserted for partial grids):
    let agg = report.aggregate();
    let eff_at = |pred: &dyn Fn(&str) -> bool| -> Vec<f64> {
        agg.iter().filter(|(l, ..)| pred(l)).map(|(_, e, ..)| *e).collect()
    };
    let small_p = eff_at(&|l: &str| l.contains("P=10 "));
    let large_p = eff_at(&|l: &str| l.ends_with(&format!("P={}", scale.p_max)) || l.contains(&format!("P={} ", scale.p_max)));
    if let (Some(lo), Some(hi)) = (
        small_p.first().copied(),
        large_p.first().copied(),
    ) {
        println!("shape check: rel.eff grows with features? {} ({lo:.2} → {hi:.2})", hi > lo);
    }
    if let Ok(dir) = std::env::var("FASTCV_BENCH_OUT") {
        std::fs::create_dir_all(&dir).ok();
        std::fs::write(format!("{dir}/fig3a.tsv"), report.to_tsv()).ok();
    }
}
