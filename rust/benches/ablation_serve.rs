//! Serve-daemon ablation: what the shared [`FactorStore`] and permutation
//! request coalescing buy a long-lived `fastcv serve` process.
//!
//! 1. **cold** — first perm request on a fresh server: pays the dataset's
//!    factor build (store miss).
//! 2. **warm** — same dataset key again: hat build served from the store.
//! 3. **coalesced pair** — two queued requests on one key merged into a
//!    single jobs-engine pass (one hat build, one fold prep, one GEMM
//!    stream spanning both requests' permutation columns).
//! 4. **serial pair** — the same two requests issued back-to-back (the
//!    store still shares the Gram, but fold prep + observed pass run
//!    twice).
//!
//! All four answer bit-identically (the serve coalescing property tests);
//! this ablation measures wall-clock only. Results go to
//! `BENCH_serve.json` (`$FASTCV_BENCH_OUT` or the working directory);
//! `FASTCV_BENCH_SCALE=tiny` shrinks the workload for CI.
//!
//! Run: `cargo bench --bench ablation_serve`

use fastcv::serve::{stats_tag, ServeConfig, Server};
use fastcv::util::json::Json;
use fastcv::util::table::{fdur, Table};
use fastcv::util::timed;
use std::collections::BTreeMap;

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let (n, p, k, n_perm) = if tiny { (40, 60, 5, 10) } else { (200, 1000, 10, 200) };
    let req = |id: usize, seed: u64| {
        format!(
            r#"{{"id":{id},"op":"perm","data":{{"synthetic":{{"n":{n},"p":{p},"seed":3}}}},"folds":{{"k":{k}}},"lambda":1.0,"n_perm":{n_perm},"seed":{seed}}}"#
        )
    };

    // Cold vs warm on one long-lived server.
    let server = Server::new(ServeConfig::default());
    let (cold_resp, t_cold) = timed(|| server.process_batch(&[req(1, 100)]));
    let (warm_resp, t_warm) = timed(|| server.process_batch(&[req(2, 100)]));
    assert!(cold_resp[0].contains("\"ok\":true"), "{}", cold_resp[0]);
    assert!(warm_resp[0].contains("\"ok\":true"), "{}", warm_resp[0]);
    let stats = server.store().stats();
    assert!(stats.hits >= 1, "warm request must hit the store: {stats:?}");

    // Coalesced pair vs the same pair served back-to-back.
    let merged = Server::new(ServeConfig::default());
    let pair = [req(3, 102), req(4, 103)];
    let (_, t_coalesced) = timed(|| merged.process_batch(&pair));
    assert_eq!(merged.coalesced(), 1, "the pair must merge into one pass");
    let serial = Server::new(ServeConfig::default());
    let (_, t_serial) = timed(|| {
        serial.process_batch(&pair[..1]);
        serial.process_batch(&pair[1..]);
    });

    let mut table = Table::new(vec!["request shape", "time", "vs cold"]).with_title(format!(
        "Ablation: fastcv serve store + coalescing (N={n} P={p} K={k}, {n_perm} perms/request)"
    ));
    let mut rows = Vec::new();
    for (name, t) in [
        ("cold (store miss)", t_cold),
        ("warm (store hit)", t_warm),
        ("pair, coalesced (1 pass)", t_coalesced),
        ("pair, serial (2 passes)", t_serial),
    ] {
        table.row(vec![name.to_string(), fdur(t), format!("{:.2}x", t / t_cold.max(1e-9))]);
        let mut row = BTreeMap::new();
        row.insert("shape".to_string(), Json::Str(name.to_string()));
        row.insert("seconds".to_string(), Json::Num(t));
        rows.push(Json::Obj(row));
    }
    println!("{}", table.render());
    println!("store after cold+warm: {}", stats_tag(&stats));

    let mut config = BTreeMap::new();
    for (key, value) in [("n", n), ("p", p), ("k", k), ("n_perm", n_perm)] {
        config.insert(key.to_string(), Json::Num(value as f64));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("serve_store".to_string()));
    doc.insert("config".to_string(), Json::Obj(config));
    doc.insert("requests".to_string(), Json::Arr(rows));
    doc.insert("cache".to_string(), Json::Str(stats_tag(&stats)));
    doc.insert("warm_speedup".to_string(), Json::Num(t_cold / t_warm.max(1e-9)));
    doc.insert(
        "coalesce_speedup".to_string(),
        Json::Num(t_serial / t_coalesced.max(1e-9)),
    );
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_serve.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
