//! Ablation: hyperparameter (λ) grid search cost — analytic CV per grid
//! point vs retrain-per-fold per grid point. The analytic path pays one
//! factorisation + hat build per λ; the standard path pays K full refits
//! per λ. With G grid points the gap multiplies.
//!
//! Run: `cargo bench --bench ablation_lambda_grid`

use fastcv::bench::Bench;
use fastcv::cv::folds::stratified_kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::lambda_search::{default_grid, search_lambda, SelectBy};
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, fnum, Table};

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let (n, p, k, g) = if tiny { (40, 30, 4, 3) } else { (120, 300, 10, 7) };
    let mut rng = Rng::new(9);
    let mut spec = SyntheticSpec::binary(n, p);
    spec.separation = 1.5;
    let ds = generate(&spec, &mut rng);
    let y = ds.y_signed();
    let folds = stratified_kfold(&ds.labels, k, &mut rng);
    let grid = default_grid(g);

    let t_analytic = bench
        .run(|| search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap())
        .median;

    let t_standard = bench
        .run(|| {
            let mut best = (f64::NEG_INFINITY, 0.0);
            for &lambda in &grid {
                if let Ok(acc) = fastcv::cv::runner::standard_binary_cv_accuracy(
                    &ds.x,
                    &ds.labels,
                    &folds,
                    Reg::Ridge(lambda),
                ) {
                    if acc > best.0 {
                        best = (acc, lambda);
                    }
                }
            }
            best
        })
        .median;

    let search = search_lambda(&ds.x, &y, &ds.labels, &folds, &grid, SelectBy::Accuracy).unwrap();

    let mut table = Table::new(vec!["method", "time", "rel.eff"])
        .with_title(format!("λ grid search: N={n} P={p} K={k}, {g} grid points"));
    table.row(vec!["standard (K refits × grid)".into(), fdur(t_standard), "1.00x ref".into()]);
    table.row(vec![
        "analytic (1 hat per λ)".into(),
        fdur(t_analytic),
        format!("{:.1}x faster", t_standard / t_analytic),
    ]);
    println!("{}", table.render());
    println!("selected λ = {} (CV acc {})", fnum(search.best_lambda(), 4), fnum(search.best_score(), 3));
}
