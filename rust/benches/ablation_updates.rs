//! Ablation (§2.4.4–2.4.6 + §2.6.2): alternative inner loops for the
//! analytic CV.
//!
//! 1. **direct** — Eq. 14 with a per-fold LU of (I − H_Te) [production path]
//! 2. **cached-lu** — Eq. 14 with fold LUs factored once and reused across
//!    permutations [production permutation path]
//! 3. **woodbury-β** — Eq. 12: materialise the fold weights β̇ and predict
//!    [what you'd do if you needed the fold models]
//! 4. **shrinkage-refit** — §2.6.2's point: shrinkage forces a full-rank
//!    update, so the "analytic" path degenerates to a refit per fold; timed
//!    here via the standard engine with shrinkage regularisation.
//!
//! Plus the **permutation-engine ablation** (serial vs batched vs
//! batched+threads) at the Fig. 3b-style scale; its timings are written to
//! `BENCH_perm.json` (`$FASTCV_BENCH_OUT` or the working directory) for the
//! perf trajectory.
//!
//! Run: `cargo bench --bench ablation_updates`

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::perm::analytic_binary_permutation;
use fastcv::fastcv::perm_batch::{analytic_binary_permutation_batched, BatchStrategy};
use fastcv::fastcv::{woodbury, FoldCache};
use fastcv::linalg::matvec;
use fastcv::model::Reg;
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::timed;
use std::collections::BTreeMap;

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let (n, p, k, n_perm) = if tiny { (40, 30, 5, 5) } else { (200, 400, 10, 50) };
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;

    let mut rng = Rng::new(5);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);

    let mut table = Table::new(vec!["variant", "time", "vs production"])
        .with_title(format!("Ablation: analytic-CV inner loops (N={n} P={p} K={k}, {n_perm} perms)"));

    let cv = AnalyticBinaryCv::fit(&ds.x, &y, lambda).unwrap();

    // 1. direct: factor per call (single-CV cost)
    let t_direct = bench.run(|| cv.decision_values(&folds).unwrap()).median;

    // 2. cached LU across permutations
    let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
    let mut cv_mut = AnalyticBinaryCv::fit(&ds.x, &y, lambda).unwrap();
    let mut perm_rng = Rng::new(99);
    let t_cached = bench
        .run(|| {
            let mut acc = 0.0;
            let mut y_perm = y.clone();
            for _ in 0..n_perm {
                perm_rng.shuffle(&mut y_perm);
                cv_mut.set_response(&y_perm);
                let dv = cv_mut.decision_values_cached(&cache);
                acc += dv[0];
            }
            acc
        })
        .median
        / n_perm as f64;

    // 2b. per-permutation refactor (Alg. 1 as printed — no LU reuse)
    let t_uncached = bench
        .run(|| {
            let mut acc = 0.0;
            let mut y_perm = y.clone();
            for _ in 0..n_perm {
                perm_rng.shuffle(&mut y_perm);
                cv_mut.set_response(&y_perm);
                let dv = cv_mut.decision_values(&folds).unwrap();
                acc += dv[0];
            }
            acc
        })
        .median
        / n_perm as f64;

    // 3. Woodbury fold weights (Eq. 12) + explicit prediction
    let t_woodbury = bench
        .run(|| {
            let mut acc = 0.0;
            for te in &folds {
                let beta = woodbury::fold_weights(&cv.hat, &y, te).unwrap();
                let xa_te = cv.hat.xa.take_rows(te);
                acc += matvec(&xa_te, &beta)[0];
            }
            acc
        })
        .median;

    // 4. shrinkage forces refits (the §2.6.2 caveat)
    let t_shrink = bench
        .run(|| {
            fastcv::cv::runner::standard_binary_cv_dvals(
                &ds.x,
                &ds.labels,
                &folds,
                Reg::Shrinkage(0.3),
            )
            .unwrap()
        })
        .median;

    let base = t_cached;
    for (name, t) in [
        ("Eq.14 direct (factor per call)", t_direct),
        ("Eq.14 cached LU (per perm)", t_cached),
        ("Eq.14 refactor every perm", t_uncached),
        ("Eq.12 Woodbury fold-weights", t_woodbury),
        ("shrinkage ⇒ full refit (§2.6.2)", t_shrink),
    ] {
        table.row(vec![name.to_string(), fdur(t), format!("{:.1}x", t / base)]);
    }
    println!("{}", table.render());

    perm_engine_ablation(tiny);
}

/// Serial vs batched vs batched+threads permutation engines at the paper's
/// Fig. 3b-style "large-P" configuration (N=256, P=2048, K=10, 1000 perms
/// by default; shrunk under FASTCV_BENCH_SCALE=tiny). Every engine produces
/// a bit-identical null distribution — this ablation measures wall-clock
/// only. Results go to BENCH_perm.json.
fn perm_engine_ablation(tiny: bool) {
    let (n, p, k, n_perm, threads) = if tiny { (40, 30, 5, 50, 2) } else { (256, 2048, 10, 1000, 8) };
    let batch = 64;
    let lambda = 1.0;
    let mut rng = Rng::new(7);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let folds = kfold(n, k, &mut rng);

    // The one-off hat/fold-cache build is shared by every engine; measure
    // the *permutation stream* as t(n_perm) − t(0) so the ablation shows
    // the quantity the engines actually change.
    let stream_time = |run: &dyn Fn(usize)| -> (f64, f64) {
        let (_, t_setup) = timed(|| run(0));
        let (_, t_total) = timed(|| run(n_perm));
        (t_total, (t_total - t_setup).max(1e-9))
    };

    let serial = |t: usize| {
        analytic_binary_permutation(&ds.x, &ds.labels, &folds, lambda, t, false, &mut Rng::new(1))
            .unwrap();
    };
    let batched_1 = |t: usize| {
        analytic_binary_permutation_batched(
            &ds.x,
            &ds.labels,
            &folds,
            lambda,
            t,
            false,
            &mut Rng::new(1),
            BatchStrategy::new(batch, 1),
        )
        .unwrap();
    };
    let batched_t = |t: usize| {
        analytic_binary_permutation_batched(
            &ds.x,
            &ds.labels,
            &folds,
            lambda,
            t,
            false,
            &mut Rng::new(1),
            BatchStrategy::new(batch, threads),
        )
        .unwrap();
    };

    let (serial_total, serial_stream) = stream_time(&serial);
    let (b1_total, b1_stream) = stream_time(&batched_1);
    let (bt_total, bt_stream) = stream_time(&batched_t);

    let mut table = Table::new(vec!["engine", "total", "perm stream", "stream speedup"])
        .with_title(format!(
            "Ablation: permutation engines (N={n} P={p} K={k}, {n_perm} perms)"
        ));
    let mut engines = Vec::new();
    for (name, total, stream) in [
        ("serial", serial_total, serial_stream),
        ("batched-b64-t1", b1_total, b1_stream),
        (if threads == 8 { "batched-b64-t8" } else { "batched-b64-tN" }, bt_total, bt_stream),
    ] {
        let speedup = serial_stream / stream;
        table.row(vec![name.to_string(), fdur(total), fdur(stream), format!("{speedup:.1}x")]);
        let mut row = BTreeMap::new();
        row.insert("engine".to_string(), Json::Str(name.to_string()));
        row.insert("seconds_total".to_string(), Json::Num(total));
        row.insert("seconds_perm_stream".to_string(), Json::Num(stream));
        row.insert("speedup_vs_serial".to_string(), Json::Num(speedup));
        engines.push(Json::Obj(row));
    }
    println!("{}", table.render());

    let mut config = BTreeMap::new();
    for (key, value) in [
        ("n", n),
        ("p", p),
        ("k", k),
        ("n_perm", n_perm),
        ("batch", batch),
        ("threads", threads),
    ] {
        config.insert(key.to_string(), Json::Num(value as f64));
    }
    config.insert("lambda".to_string(), Json::Num(lambda));
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("perm_engines".to_string()));
    doc.insert("config".to_string(), Json::Obj(config));
    doc.insert("engines".to_string(), Json::Arr(engines));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_perm.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
