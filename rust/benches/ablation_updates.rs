//! Ablation (§2.4.4–2.4.6 + §2.6.2): alternative inner loops for the
//! analytic CV.
//!
//! 1. **direct** — Eq. 14 with a per-fold LU of (I − H_Te) [production path]
//! 2. **cached-lu** — Eq. 14 with fold LUs factored once and reused across
//!    permutations [production permutation path]
//! 3. **woodbury-β** — Eq. 12: materialise the fold weights β̇ and predict
//!    [what you'd do if you needed the fold models]
//! 4. **shrinkage-refit** — §2.6.2's point: shrinkage forces a full-rank
//!    update, so the "analytic" path degenerates to a refit per fold; timed
//!    here via the standard engine with shrinkage regularisation.
//!
//! Run: `cargo bench --bench ablation_updates`

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::{woodbury, FoldCache};
use fastcv::linalg::matvec;
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let (n, p, k, n_perm) = if tiny { (40, 30, 5, 5) } else { (200, 400, 10, 50) };
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;

    let mut rng = Rng::new(5);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let y = ds.y_signed();
    let folds = kfold(n, k, &mut rng);

    let mut table = Table::new(vec!["variant", "time", "vs production"])
        .with_title(format!("Ablation: analytic-CV inner loops (N={n} P={p} K={k}, {n_perm} perms)"));

    let cv = AnalyticBinaryCv::fit(&ds.x, &y, lambda).unwrap();

    // 1. direct: factor per call (single-CV cost)
    let t_direct = bench.run(|| cv.decision_values(&folds).unwrap()).median;

    // 2. cached LU across permutations
    let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
    let mut cv_mut = AnalyticBinaryCv::fit(&ds.x, &y, lambda).unwrap();
    let mut perm_rng = Rng::new(99);
    let t_cached = bench
        .run(|| {
            let mut acc = 0.0;
            let mut y_perm = y.clone();
            for _ in 0..n_perm {
                perm_rng.shuffle(&mut y_perm);
                cv_mut.set_response(&y_perm);
                let dv = cv_mut.decision_values_cached(&cache);
                acc += dv[0];
            }
            acc
        })
        .median
        / n_perm as f64;

    // 2b. per-permutation refactor (Alg. 1 as printed — no LU reuse)
    let t_uncached = bench
        .run(|| {
            let mut acc = 0.0;
            let mut y_perm = y.clone();
            for _ in 0..n_perm {
                perm_rng.shuffle(&mut y_perm);
                cv_mut.set_response(&y_perm);
                let dv = cv_mut.decision_values(&folds).unwrap();
                acc += dv[0];
            }
            acc
        })
        .median
        / n_perm as f64;

    // 3. Woodbury fold weights (Eq. 12) + explicit prediction
    let t_woodbury = bench
        .run(|| {
            let mut acc = 0.0;
            for te in &folds {
                let beta = woodbury::fold_weights(&cv.hat, &y, te).unwrap();
                let xa_te = cv.hat.xa.take_rows(te);
                acc += matvec(&xa_te, &beta)[0];
            }
            acc
        })
        .median;

    // 4. shrinkage forces refits (the §2.6.2 caveat)
    let t_shrink = bench
        .run(|| {
            fastcv::cv::runner::standard_binary_cv_dvals(
                &ds.x,
                &ds.labels,
                &folds,
                Reg::Shrinkage(0.3),
            )
            .unwrap()
        })
        .median;

    let base = t_cached;
    for (name, t) in [
        ("Eq.14 direct (factor per call)", t_direct),
        ("Eq.14 cached LU (per perm)", t_cached),
        ("Eq.14 refactor every perm", t_uncached),
        ("Eq.12 Woodbury fold-weights", t_woodbury),
        ("shrinkage ⇒ full refit (§2.6.2)", t_shrink),
    ] {
        table.row(vec![name.to_string(), fdur(t), format!("{:.1}x", t / base)]);
    }
    println!("{}", table.render());
}
