//! Table 1 — empirical complexity: fit scaling exponents of both approaches
//! against N, P, K and compare with the asymptotic predictions
//! (standard: O(KNP² + KP³); analytic: O(KN³) after an O(N²P + NP² + P³)
//! hat build).
//!
//! Run: `cargo bench --bench table1_scaling`
//! Env: FASTCV_BENCH_SCALE=tiny for a fast smoke run.

use fastcv::bench::Bench;
use fastcv::cv::folds::kfold;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::fastcv::hat::GramBackend;
use fastcv::fastcv::FoldCache;
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fnum, Table};

fn fit_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let mx = fastcv::util::mean(&lx);
    let my = fastcv::util::mean(&ly);
    let num: f64 = lx.iter().zip(&ly).map(|(a, b)| (a - mx) * (b - my)).sum();
    let den: f64 = lx.iter().map(|a| (a - mx) * (a - mx)).sum();
    num / den
}

fn time_pair(n: usize, p: usize, k: usize, bench: &Bench) -> (f64, f64) {
    let mut rng = Rng::new((n * 31 + p * 7 + k) as u64);
    let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
    let folds = kfold(n, k, &mut rng);
    let y = ds.y_signed();
    let t_std = bench
        .run(|| {
            fastcv::cv::runner::standard_binary_cv_dvals(&ds.x, &ds.labels, &folds, Reg::Ridge(1.0))
                .unwrap()
        })
        .median;
    let t_ana = bench
        .run(|| {
            let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
            let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
            cv.decision_values_cached(&cache)
        })
        .median;
    (t_std, t_ana)
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny { Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 } } else { Bench::quick() };

    let mut table = Table::new(vec!["axis", "standard slope", "analytic slope", "paper prediction"])
        .with_title("Table 1 — empirical scaling exponents (log-log slopes)".to_string());

    // --- vs P (N, K fixed; P past N so the P³ term dominates the standard arm) ---
    let ps: Vec<usize> = if tiny { vec![30, 60, 120] } else { vec![100, 200, 400, 800] };
    let n = if tiny { 24 } else { 80 };
    let (mut ts, mut ta) = (Vec::new(), Vec::new());
    for &p in &ps {
        let (s, a) = time_pair(n, p, 8.min(n / 3), &bench);
        ts.push(s);
        ta.push(a);
    }
    let xs: Vec<f64> = ps.iter().map(|&p| p as f64).collect();
    table.row(vec![
        format!("time vs P (N={n})"),
        format!("P^{}", fnum(fit_slope(&xs, &ts), 2)),
        format!("P^{}", fnum(fit_slope(&xs, &ta), 2)),
        "std ~P³ (P>N); ana ≤P² (hat build only)".into(),
    ]);

    // --- vs N (P, K fixed) ---
    let ns: Vec<usize> = if tiny { vec![24, 48, 96] } else { vec![100, 200, 400] };
    let p = if tiny { 16 } else { 60 };
    let (mut ts, mut ta) = (Vec::new(), Vec::new());
    for &n in &ns {
        let (s, a) = time_pair(n, p, 8, &bench);
        ts.push(s);
        ta.push(a);
    }
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    table.row(vec![
        format!("time vs N (P={p})"),
        format!("N^{}", fnum(fit_slope(&xs, &ts), 2)),
        format!("N^{}", fnum(fit_slope(&xs, &ta), 2)),
        "std ~N (scatter accum); ana ~N²··³ (K·(N/K)³ + N²P)".into(),
    ]);

    // --- vs K (N, P fixed) ---
    let ks: Vec<usize> = if tiny { vec![2, 4, 8] } else { vec![2, 5, 10, 20] };
    let (n, p) = if tiny { (24, 16) } else { (120, 150) };
    let (mut ts, mut ta) = (Vec::new(), Vec::new());
    for &k in &ks {
        let (s, a) = time_pair(n, p, k, &bench);
        ts.push(s);
        ta.push(a);
    }
    let xs: Vec<f64> = ks.iter().map(|&k| k as f64).collect();
    table.row(vec![
        format!("time vs K (N={n} P={p})"),
        format!("K^{}", fnum(fit_slope(&xs, &ts), 2)),
        format!("K^{}", fnum(fit_slope(&xs, &ta), 2)),
        "std ~K (K refits); ana ~K⁻² per-fold shrink (K·(N/K)³)".into(),
    ]);

    println!("{}", table.render());

    // --- Gram backends vs P (N fixed, P past N): the primal analytic arm
    // inherits a P³ factor, the dual arm is linear in P — the P ≫ N
    // asymptotics that motivated the backend abstraction. ---
    let time_backend = |n: usize, p: usize, backend: GramBackend, bench: &Bench| -> f64 {
        let mut rng = Rng::new((n * 17 + p * 3) as u64);
        let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
        let folds = kfold(n, 8.min(n / 3), &mut rng);
        let y = ds.y_signed();
        bench
            .run(|| {
                let cv = AnalyticBinaryCv::fit_with(&ds.x, &y, 1.0, backend).unwrap();
                let cache = FoldCache::prepare(&cv.hat, &folds, false).unwrap();
                cv.decision_values_cached(&cache)
            })
            .median
    };
    let ps: Vec<usize> = if tiny { vec![30, 60, 120] } else { vec![100, 200, 400, 800] };
    let n = if tiny { 24 } else { 80 };
    let (mut t_primal, mut t_dual) = (Vec::new(), Vec::new());
    for &p in &ps {
        t_primal.push(time_backend(n, p, GramBackend::Primal, &bench));
        t_dual.push(time_backend(n, p, GramBackend::Dual, &bench));
    }
    let xs: Vec<f64> = ps.iter().map(|&p| p as f64).collect();
    let mut bt = Table::new(vec!["axis", "primal slope", "dual slope", "prediction"])
        .with_title("Gram backends — analytic-arm scaling exponents".to_string());
    bt.row(vec![
        format!("time vs P (N={n})"),
        format!("P^{}", fnum(fit_slope(&xs, &t_primal), 2)),
        format!("P^{}", fnum(fit_slope(&xs, &t_dual), 2)),
        "primal ~P²··³ (gram+factor); dual ~P (K_c build only)".into(),
    ]);
    println!("{}", bt.render());
}
