//! Ablation: tiled vs one-shot `N×N` Gram builds (the §4.5 memory-bounded
//! engine) → `BENCH_tiling.json`.
//!
//! Over an N/P/tile grid, measures
//!
//! 1. the dual **streaming-hat** build (`StreamingHat`): one-shot
//!    (`TilePolicy::Off` — full centered copy + transpose + out-of-place
//!    Cholesky) vs tiled (slab-assembled `K_c`, in-place blocked factor,
//!    in-place solve), and
//! 2. the dual **GramCache** `K_c` build, one-shot vs tiled,
//!
//! with a **resident-bytes estimate** column per arm (the accounting
//! documented in `docs/BACKENDS.md` "Memory-bounded builds"): beyond the
//! `O(NP)` outputs both arms share, the one-shot build transiently holds
//! `X_c` + its transpose + `K_c` + a second `N×N` for the factor + an
//! `N×P` solve clone, while the tiled build holds the in-place factor
//! (the irreducible `N×N` of the single-λ dual form) plus `tile`-bounded
//! slabs only. Bitwise equality of the two arms rides along so the JSON
//! records correctness, not just speed.
//!
//! Env: `FASTCV_BENCH_SCALE=tiny` for a fast smoke run (CI);
//! `FASTCV_BENCH_OUT` for the output directory.
//! Run: `cargo bench --bench ablation_tiling`

use fastcv::bench::Bench;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::bigdata::StreamingHat;
use fastcv::fastcv::hat::{GramBackend, GramCache};
use fastcv::fastcv::ComputeContext;
use fastcv::linalg::TilePolicy;
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use std::collections::BTreeMap;

/// Transient resident-bytes estimate of the one-shot dual streaming build,
/// beyond the `xa`/`t` outputs both arms share: `X_c` (N·P) + its transpose
/// copy (N·P) + `K_c + λI` (N²) + the out-of-place factor `L` (N²) + the
/// solve's RHS clone (N·P).
fn resident_one_shot(n: usize, p: usize) -> usize {
    8 * (2 * n * n + 3 * n * p)
}

/// Transient resident-bytes estimate of the tiled build: the in-place
/// factor (N², irreducible for a single-λ dual solve) + the centered RHS
/// solved in place (N·P) + three `tile×P` slabs (own band, partner band,
/// partner's transposed copy) and a `tile×N` output strip per worker.
fn resident_tiled(n: usize, p: usize, tile: usize) -> usize {
    8 * (n * n + n * p + tile * (3 * p + n))
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;
    // Wide shapes only: tiling targets the P ≫ N (dual/spectral) quadrant.
    let shapes: &[(usize, usize)] = if tiny { &[(24, 96)] } else { &[(100, 800), (200, 1600)] };

    let mut rows = Vec::new();
    let mut table = Table::new(vec![
        "shape",
        "tile",
        "stream one-shot",
        "stream tiled",
        "K_c one-shot",
        "K_c tiled",
        "resident tiled/one-shot",
        "bitwise",
    ])
    .with_title("Ablation: tiled vs one-shot N×N Gram builds (dual backend)".to_string());

    for &(n, p) in shapes {
        let mut rng = Rng::new((n * 37 + p) as u64);
        let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
        let tiles: Vec<usize> = if tiny { vec![4, n / 2] } else { vec![16, 64, n / 2] };

        let t_stream_off = bench
            .run(|| StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap())
            .median;
        let t_kc_off =
            bench.run(|| GramCache::build(&ds.x, GramBackend::Dual, None)).median;
        let reference =
            StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();
        let kc_reference = GramCache::build(&ds.x, GramBackend::Dual, None);

        for tile in tiles {
            let ctx = ComputeContext::with_threads(if tiny { 2 } else { 4 })
                .with_backend(GramBackend::Dual)
                .with_tile_policy(TilePolicy::Rows(tile));
            let t_stream_tiled =
                bench.run(|| StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap()).median;
            let t_kc_tiled = bench
                .run(|| {
                    GramCache::build_tiled(
                        &ds.x,
                        GramBackend::Dual,
                        ctx.pool(),
                        TilePolicy::Rows(tile),
                    )
                    .unwrap()
                })
                .median;

            // correctness rides along: both arms bitwise-equal
            let tiled = StreamingHat::build_ctx(&ds.x, lambda, &ctx).unwrap();
            let kc_tiled = GramCache::build_tiled(
                &ds.x,
                GramBackend::Dual,
                ctx.pool(),
                TilePolicy::Rows(tile),
            )
            .unwrap();
            let (GramCache::Dual { kc: kc_a, .. }, GramCache::Dual { kc: kc_b, .. }) =
                (&kc_reference, &kc_tiled)
            else {
                unreachable!()
            };
            let bitwise = reference.t.as_slice() == tiled.t.as_slice()
                && kc_a.as_slice() == kc_b.as_slice();

            let res_off = resident_one_shot(n, p);
            let res_tiled = resident_tiled(n, p, tile);
            let ratio = res_tiled as f64 / res_off as f64;
            table.row(vec![
                format!("N={n} P={p}"),
                format!("{tile}"),
                fdur(t_stream_off),
                fdur(t_stream_tiled),
                fdur(t_kc_off),
                fdur(t_kc_tiled),
                format!("{ratio:.2}"),
                format!("{bitwise}"),
            ]);
            let mut row = BTreeMap::new();
            row.insert("n".to_string(), Json::Num(n as f64));
            row.insert("p".to_string(), Json::Num(p as f64));
            row.insert("tile".to_string(), Json::Num(tile as f64));
            row.insert("seconds_stream_one_shot".to_string(), Json::Num(t_stream_off));
            row.insert("seconds_stream_tiled".to_string(), Json::Num(t_stream_tiled));
            row.insert("seconds_kc_one_shot".to_string(), Json::Num(t_kc_off));
            row.insert("seconds_kc_tiled".to_string(), Json::Num(t_kc_tiled));
            row.insert("resident_bytes_one_shot".to_string(), Json::Num(res_off as f64));
            row.insert("resident_bytes_tiled".to_string(), Json::Num(res_tiled as f64));
            row.insert("resident_ratio".to_string(), Json::Num(ratio));
            row.insert("bitwise_identical".to_string(), Json::Bool(bitwise));
            rows.push(Json::Obj(row));
        }
    }
    println!("{}", table.render());
    println!(
        "resident-bytes model: one-shot = 8·(2N² + 3NP), tiled = 8·(N² + NP + tile·(3P + N)) \
         — transients beyond the shared O(NP) outputs; see docs/BACKENDS.md"
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("tiled_gram_builds".to_string()));
    doc.insert("lambda".to_string(), Json::Num(lambda));
    doc.insert("grid".to_string(), Json::Arr(rows));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_tiling.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
