//! Fig. 3b — BinaryPerm sweep: relative efficiency over the feature grid,
//! with the analytic arm run by both the serial and the batched+threaded
//! permutation engines (identical accuracies by the determinism contract;
//! only timing differs).
//!
//! Timing protocol: both passes run one point at a time (a 1-worker
//! scheduler for the serial pass, a plain loop for the batched pass) so the
//! engine comparison is not confounded by scheduler-level CPU contention,
//! and the expensive standard arm is measured once — the batched pass
//! reuses the serial pass's `t_std` instead of re-running it.
//!
//! Scale via env: FASTCV_BENCH_SCALE=tiny|medium|paper (default medium).
//! Run: `cargo bench --bench fig3_binary_perm`

use fastcv::coordinator::scheduler::job_seed;
use fastcv::coordinator::sweep::{
    grid, run_point_analytic_perm, Experiment, PermEngine, SweepScale,
};
use fastcv::coordinator::{Scheduler, SweepReport};

fn scale_from_env() -> SweepScale {
    match std::env::var("FASTCV_BENCH_SCALE").as_deref() {
        Ok("tiny") => SweepScale::tiny(),
        Ok("paper") => SweepScale::paper(),
        _ => SweepScale::medium(),
    }
}

fn main() {
    let scale = scale_from_env();
    let seed = 2018u64;
    let serial_points = grid(Experiment::BinaryPerm, &scale);
    let threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    eprintln!("fig3b: {} sweep points × 2 engines", serial_points.len());

    let serial_results = Scheduler::new(1, seed, true).run(&serial_points);
    let serial_report = SweepReport::new(serial_results);
    println!("{}", serial_report.render("Fig. 3b — BinaryPerm (serial analytic engine)"));

    // Batched pass: analytic arm only, standard-arm timings reused from the
    // serial pass (same point → same seed → identical data and folds).
    let mut batched_results = Vec::new();
    for (i, point) in serial_points.iter().enumerate() {
        let point = point.with_engine(PermEngine::Batched { batch: 64, threads });
        match run_point_analytic_perm(&point, job_seed(seed, i)) {
            Ok(mut r) => {
                if let Some(s) = serial_report.results.iter().find(|s| {
                    s.n == r.n && s.p == r.p && s.n_perm == r.n_perm && s.rep == r.rep
                }) {
                    r.t_std = s.t_std;
                    r.acc_std = s.acc_std;
                }
                batched_results.push(r);
            }
            Err(e) => eprintln!("batched point {} failed: {e:#}", point.label()),
        }
    }
    let batched_report = SweepReport::new(batched_results);
    println!(
        "{}",
        batched_report
            .render(&format!("Fig. 3b — BinaryPerm (batched engine, B=64 T={threads})"))
    );
    if let Ok(dir) = std::env::var("FASTCV_BENCH_OUT") {
        std::fs::create_dir_all(&dir).ok();
        let mut tsv = serial_report.to_tsv();
        // Append batched rows minus the duplicated header.
        let batched_tsv = batched_report.to_tsv();
        if let Some((_, body)) = batched_tsv.split_once('\n') {
            tsv.push_str(body);
        }
        std::fs::write(format!("{dir}/fig3b.tsv"), tsv).ok();
    }
}
