//! Fig. 3b — BinaryPerm sweep: relative efficiency over the feature grid.
//! Scale via env: FASTCV_BENCH_SCALE=tiny|medium|paper (default medium).
//! Run: `cargo bench --bench fig3_binary_perm`

use fastcv::coordinator::sweep::{grid, Experiment, SweepScale};
use fastcv::coordinator::{Scheduler, SweepReport};

fn scale_from_env() -> SweepScale {
    match std::env::var("FASTCV_BENCH_SCALE").as_deref() {
        Ok("tiny") => SweepScale::tiny(),
        Ok("paper") => SweepScale::paper(),
        _ => SweepScale::medium(),
    }
}

fn main() {
    let scale = scale_from_env();
    let points = grid(Experiment::BinaryPerm, &scale);
    eprintln!("fig3b: {} sweep points", points.len());
    let sched = Scheduler::new(0, 2018, true);
    let report = SweepReport::new(sched.run(&points));
    println!("{}", report.render("Fig. 3b — BinaryPerm"));
    if let Ok(dir) = std::env::var("FASTCV_BENCH_OUT") {
        std::fs::create_dir_all(&dir).ok();
        std::fs::write(format!("{dir}/fig3b.tsv"), report.to_tsv()).ok();
    }
}
