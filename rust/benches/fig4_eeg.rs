//! Fig. 4 — EEG/MEG permutation study on the simulated Wakeman–Henson
//! substitute: per-subject relative efficiency for binary (380 / 3800
//! features) and multi-class (380 / 1900 features) LDA, 100 permutations ×
//! 10-fold CV.
//!
//! Run: `cargo bench --bench fig4_eeg`
//! Env: FASTCV_BENCH_SCALE=tiny  → 2 small subjects, 5 perms (smoke)
//!      FASTCV_BENCH_SCALE=paper → 16 subjects at full channel count

use fastcv::bench::RelEffReport;
use fastcv::cv::folds::stratified_kfold;
use fastcv::data::eeg::{simulate_subject, EegSpec};
use fastcv::fastcv::perm::*;
use fastcv::model::Reg;
use fastcv::util::rng::Rng;
use fastcv::util::timed;

fn main() {
    let scale = std::env::var("FASTCV_BENCH_SCALE").unwrap_or_default();
    let (n_subj, n_perm, spec) = match scale.as_str() {
        "tiny" => (2usize, 5usize, EegSpec::small()),
        "paper" => (16, 100, EegSpec::default()),
        _ => (
            4,
            25,
            EegSpec { n_channels: 96, mean_trials: 200, trial_jitter: 20, snr: 1.2 },
        ),
    };
    let lambda = 1.0;
    eprintln!("fig4: {n_subj} subjects, {} channels, {n_perm} perms", spec.n_channels);

    let mut root = Rng::new(2018);
    let mut report = RelEffReport::new("Fig. 4 — per-subject relative efficiency (permutations)");
    // factors for the paper's §3.2 two-way ANOVA: features (small/large) ×
    // classifier (binary/multi)
    let mut anova_y = Vec::new();
    let mut f_features = Vec::new();
    let mut f_classifier = Vec::new();
    for subj in 0..n_subj {
        let mut rng = root.fork(subj as u64 + 1);
        let subject = simulate_subject(&spec, &mut rng);
        let peak = ((0.17f64 + 0.5) * 200.0) as usize;
        // (analysis, binary?, dataset)
        let cases = vec![
            ("bin-small", true, subject.features_at_timepoint(peak, true)),
            ("bin-large", true, subject.features_windowed(100, true)),
            ("mc-small", false, subject.features_at_timepoint(peak, false)),
            ("mc-large", false, subject.features_windowed(200, false)),
        ];
        for (name, binary, ds) in cases {
            let folds = stratified_kfold(&ds.labels, 10, &mut rng);
            let mut r_std = rng.fork(3);
            let mut r_ana = rng.fork(3);
            let (t_std, t_ana) = if binary {
                let (a, t1) = timed(|| {
                    standard_binary_permutation(&ds.x, &ds.labels, &folds, Reg::Ridge(lambda), n_perm, &mut r_std)
                        .unwrap()
                });
                let (b, t2) = timed(|| {
                    analytic_binary_permutation(&ds.x, &ds.labels, &folds, lambda, n_perm, false, &mut r_ana)
                        .unwrap()
                });
                assert!((a.observed - b.observed).abs() < 0.2);
                (t1, t2)
            } else {
                let (a, t1) = timed(|| {
                    standard_multiclass_permutation(&ds.x, &ds.labels, 3, &folds, Reg::Ridge(lambda), n_perm, &mut r_std)
                        .unwrap()
                });
                let (b, t2) = timed(|| {
                    analytic_multiclass_permutation(&ds.x, &ds.labels, 3, &folds, lambda, n_perm, &mut r_ana)
                        .unwrap()
                });
                assert!((a.observed - b.observed).abs() < 1e-9, "multiclass must agree exactly");
                (t1, t2)
            };
            report.push(&format!("subj{subj:02} {name} P={}", ds.p()), t_std, t_ana);
            anova_y.push((t_std / t_ana).log10());
            f_features.push(usize::from(name.ends_with("large")));
            f_classifier.push(usize::from(!binary));
            eprintln!("  subj{subj:02} {name} P={} done", ds.p());
        }
    }
    println!("{}", report.render());
    // §3.2's two-way ANOVA: features (small=380-ish, large) × classifier.
    if anova_y.len() >= 8 {
        use fastcv::stats::anova::{anova, Factor};
        let tab = anova(
            &anova_y,
            &[Factor::new("features", &f_features), Factor::new("classifier", &f_classifier)],
        );
        println!(
            "{}",
            fastcv::coordinator::SweepReport::render_anova(
                &tab,
                "Fig. 4 — two-way ANOVA on rel.eff (features × classifier, cf. §3.2)"
            )
        );
    }
    if let Ok(dir) = std::env::var("FASTCV_BENCH_OUT") {
        std::fs::create_dir_all(&dir).ok();
        std::fs::write(format!("{dir}/fig4.tsv"), report.to_tsv()).ok();
    }
}
