//! Streaming-engine ablation: what maintaining the sliding window's
//! Cholesky factor by rank-1 up-downdates buys over rebuilding it.
//!
//! For each window shape `(N, P)` the bench times one full **window step**
//! of the factor work both ways (docs/STREAM.md):
//!
//! 1. **incremental** — evict the oldest row (hyperbolic downdate) +
//!    append the new one (Givens update): `O(P²)`, what
//!    [`fastcv::fastcv::incremental::SlidingWindowCv`] does per step.
//! 2. **rebuild** — assemble the window matrix, `syrk` the augmented
//!    Gram, add the ridge, refactor: `O(NP² + P³)`, what `--rebuild`
//!    (and every step of a naive streaming loop) pays.
//!
//! Both arms exclude the CV evaluation itself — that cost is identical in
//! the two modes, and the engine's claim is about factor maintenance.
//! Results go to `BENCH_stream.json` (`$FASTCV_BENCH_OUT` or the working
//! directory); `FASTCV_BENCH_SCALE=tiny` shrinks the workload for CI. The
//! bench asserts the headline contract: ≥ 10× per step at the largest
//! benched window.
//!
//! Run: `cargo bench --bench ablation_stream`

use fastcv::linalg::{chol_downdate, chol_update, syrk_t, Cholesky, Mat};
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::timed;
use std::collections::{BTreeMap, VecDeque};

const LAMBDA: f64 = 1.0;

/// One augmented sample row `x̃ = [x, 1]`.
fn sample_row(rng: &mut Rng, p: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..p).map(|_| rng.gauss()).collect();
    v.push(1.0);
    v
}

/// Exact factor of the window's ridged augmented Gram (the rebuild arm's
/// unit of work, minus the matrix assembly measured separately below).
fn factor_window(window: &VecDeque<Vec<f64>>, p: usize) -> Cholesky {
    let n = window.len();
    let xa = Mat::from_fn(n, p + 1, |i, j| window[i][j]);
    let mut g = syrk_t(&xa);
    for i in 0..p {
        g[(i, i)] += LAMBDA;
    }
    Cholesky::factor(&g).expect("ridged augmented gram is SPD")
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    // (window N, features P, steps timed per arm). The ratio is ~N + P by
    // the flop counts, so it grows with the window — "largest benched N"
    // is the headline row.
    let shapes: &[(usize, usize, usize)] = if tiny {
        &[(64, 16, 400), (192, 24, 400)]
    } else {
        &[(256, 64, 400), (512, 96, 200), (1024, 128, 100)]
    };

    let mut table = Table::new(vec!["window", "incremental/step", "rebuild/step", "speedup"])
        .with_title("Ablation: streaming factor maintenance vs per-step rebuild".to_string());
    let mut rows = Vec::new();
    let mut last_speedup = 0.0;
    let mut checksum = 0.0;

    for &(n, p, steps) in shapes {
        let mut rng = Rng::new(2018);
        let mut window: VecDeque<Vec<f64>> = (0..n).map(|_| sample_row(&mut rng, p)).collect();
        let mut fresh: VecDeque<Vec<f64>> = (0..steps).map(|_| sample_row(&mut rng, p)).collect();

        // Incremental arm: downdate the evicted row, update the appended
        // one — the factor work of one SlidingWindowCv step.
        let mut ch = factor_window(&window, p);
        let (_, t_inc) = timed(|| {
            for _ in 0..steps {
                let old = window.pop_front().expect("window is non-empty");
                chol_downdate(&mut ch, &old).expect("well-ridged window stays SPD");
                let new = fresh.pop_front().expect("enough fresh samples");
                chol_update(&mut ch, &new);
                fresh.push_back(old);
                window.push_back(new);
            }
        });
        checksum += ch.l()[(p, p)];

        // Rebuild arm: the same window rotation, but the factor comes from
        // matrix assembly + syrk + refactor every step (fewer reps — each
        // one is the expensive path).
        let rebuild_steps = (steps / 10).max(3);
        let (_, t_reb) = timed(|| {
            for _ in 0..rebuild_steps {
                let old = window.pop_front().expect("window is non-empty");
                let new = fresh.pop_front().expect("enough fresh samples");
                fresh.push_back(old);
                window.push_back(new);
                checksum += factor_window(&window, p).l()[(p, p)];
            }
        });

        let per_inc = t_inc / steps as f64;
        let per_reb = t_reb / rebuild_steps as f64;
        let speedup = per_reb / per_inc.max(1e-12);
        last_speedup = speedup;
        table.row(vec![
            format!("N={n} P={p}"),
            fdur(per_inc),
            fdur(per_reb),
            format!("{speedup:.1}x"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), Json::Num(n as f64));
        row.insert("p".to_string(), Json::Num(p as f64));
        row.insert("seconds_incremental_step".to_string(), Json::Num(per_inc));
        row.insert("seconds_rebuild_step".to_string(), Json::Num(per_reb));
        row.insert("speedup".to_string(), Json::Num(speedup));
        rows.push(Json::Obj(row));
    }

    println!("{}", table.render());
    println!("(factor checksum {checksum:.6e})");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("stream_window_step".to_string()));
    doc.insert("lambda".to_string(), Json::Num(LAMBDA));
    doc.insert("windows".to_string(), Json::Arr(rows));
    doc.insert("speedup_at_largest".to_string(), Json::Num(last_speedup));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_stream.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    assert!(
        last_speedup >= 10.0,
        "incremental step must be ≥ 10x the rebuild at the largest window \
         (got {last_speedup:.1}x) — the O(P²) vs O(NP² + P³) contract"
    );
}
