//! L3 hot-path microbenchmarks: gemm / syrk / Cholesky / LU throughput.
//! These are the kernels both CV arms sit on; the §Perf pass tracks them.
//!
//! Run: `cargo bench --bench linalg_kernels`

use fastcv::bench::Bench;
use fastcv::fastcv::bigdata::SparseProjection;
use fastcv::linalg::{matmul, matmul_pool, syrk_t, syrk_tiled, Cholesky, Lu, Mat};
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::threadpool::ThreadPool;

fn gflops(flops: f64, secs: f64) -> String {
    format!("{:.2}", flops / secs / 1e9)
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::default()
    };
    let mut rng = Rng::new(1);
    let mut table = Table::new(vec!["kernel", "size", "time", "GFLOP/s"])
        .with_title("linalg kernel throughput (f64)".to_string());

    let sizes: &[usize] = if tiny { &[64, 128] } else { &[128, 256, 512] };
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, s, |_, _| rng.gauss());
        let t = bench.run(|| matmul(&a, &b)).median;
        table.row(vec![
            "gemm".into(),
            format!("{s}x{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64, t),
        ]);
    }
    // Pack-bound GEMM: a skinny B (8 columns) makes the A-packing loop the
    // dominant cost, so this arm tracks the slice-based `pack_a`/`pack_b`
    // rewrite (bitwise-identical packing; see linalg::gemm).
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, 8, |_, _| rng.gauss());
        let t = bench.run(|| matmul(&a, &b)).median;
        table.row(vec![
            "gemm (pack-bound)".into(),
            format!("{s}x{s}x8"),
            fdur(t),
            gflops(2.0 * (s * s * 8) as f64, t),
        ]);
    }
    for &s in sizes {
        let a = Mat::from_fn(2 * s, s, |_, _| rng.gauss());
        let t = bench.run(|| syrk_t(&a)).median;
        table.row(vec![
            "syrk (XᵀX)".into(),
            format!("{}x{s}", 2 * s),
            fdur(t),
            gflops((2 * s) as f64 * (s * s) as f64, t),
        ]);
        // the banded form (tiled primal syrk) — bitwise-equal output
        let t = bench.run(|| syrk_tiled(&a, 64, None)).median;
        table.row(vec![
            "syrk_tiled (64-row bands)".into(),
            format!("{}x{s}", 2 * s),
            fdur(t),
            gflops((2 * s) as f64 * (s * s) as f64, t),
        ]);
    }
    for &s in sizes {
        let a = Mat::from_fn(s + 8, s, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..s {
            g[(i, i)] += 1.0;
        }
        let t = bench.run(|| Cholesky::factor(&g).unwrap()).median;
        table.row(vec![
            "cholesky".into(),
            format!("{s}x{s}"),
            fdur(t),
            gflops((s * s * s) as f64 / 3.0, t),
        ]);
        let t = bench.run(|| Lu::factor(&g).unwrap()).median;
        table.row(vec![
            "lu".into(),
            format!("{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64 / 3.0, t),
        ]);
    }
    // pool-parallel GEMM (the dual backend's K_c build path)
    let pool = ThreadPool::with_default_size(8);
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, s, |_, _| rng.gauss());
        let t = bench.run(|| matmul_pool(&a, &b, Some(&pool))).median;
        table.row(vec![
            format!("gemm (pool×{})", pool.size()),
            format!("{s}x{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64, t),
        ]);
    }
    // CSC sparse random projection (bigdata §4.5 "too many features" path);
    // ~1/3 density, flops ≈ 2·nnz·N
    let (n, p, q) = if tiny { (32, 500, 64) } else { (64, 2000, 256) };
    let x = Mat::from_fn(n, p, |_, _| rng.gauss());
    let proj = SparseProjection::sample(p, q, &mut rng);
    let t = bench.run(|| proj.project(&x)).median;
    table.row(vec![
        "sparse-projection (CSC)".into(),
        format!("{n}x{p}→{q}"),
        fdur(t),
        gflops(2.0 * proj.density() * (p * q * n) as f64, t),
    ]);
    println!("{}", table.render());
}
