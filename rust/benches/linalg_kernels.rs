//! L3 hot-path microbenchmarks: gemm / syrk / Cholesky / LU throughput.
//! These are the kernels both CV arms sit on; the §Perf pass tracks them.
//!
//! The per-ISA arms time the same canonical-order kernels under every ISA
//! the host supports (scalar reference plus AVX2/NEON when detected — see
//! `linalg::dispatch` and docs/BACKENDS.md "Kernel dispatch") and write the
//! timings to `BENCH_gemm.json` (`$FASTCV_BENCH_OUT` or the working
//! directory) with per-arm `speedup_vs_scalar` for the perf trajectory.
//! Bitwise equality across arms is pinned elsewhere (`kernel_conformance_*`);
//! this file only times them.
//!
//! Run: `cargo bench --bench linalg_kernels`

use std::collections::BTreeMap;

use fastcv::bench::Bench;
use fastcv::fastcv::bigdata::SparseProjection;
use fastcv::linalg::{
    matmul, matmul_isa, matmul_pool, syrk_t, syrk_t_isa, syrk_tiled, Cholesky, Isa, Lu, Mat,
};
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use fastcv::util::threadpool::ThreadPool;

fn gflops(flops: f64, secs: f64) -> String {
    format!("{:.2}", flops / secs / 1e9)
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::default()
    };
    let mut rng = Rng::new(1);
    let mut table = Table::new(vec!["kernel", "size", "time", "GFLOP/s"])
        .with_title("linalg kernel throughput (f64)".to_string());

    let sizes: &[usize] = if tiny { &[64, 128] } else { &[128, 256, 512] };
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, s, |_, _| rng.gauss());
        let t = bench.run(|| matmul(&a, &b)).median;
        table.row(vec![
            "gemm".into(),
            format!("{s}x{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64, t),
        ]);
    }
    // Pack-bound GEMM: a skinny B (8 columns) makes the A-packing loop the
    // dominant cost, so this arm tracks the slice-based `pack_a`/`pack_b`
    // rewrite (bitwise-identical packing; see linalg::gemm).
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, 8, |_, _| rng.gauss());
        let t = bench.run(|| matmul(&a, &b)).median;
        table.row(vec![
            "gemm (pack-bound)".into(),
            format!("{s}x{s}x8"),
            fdur(t),
            gflops(2.0 * (s * s * 8) as f64, t),
        ]);
    }
    for &s in sizes {
        let a = Mat::from_fn(2 * s, s, |_, _| rng.gauss());
        let t = bench.run(|| syrk_t(&a)).median;
        table.row(vec![
            "syrk (XᵀX)".into(),
            format!("{}x{s}", 2 * s),
            fdur(t),
            gflops((2 * s) as f64 * (s * s) as f64, t),
        ]);
        // the banded form (tiled primal syrk) — bitwise-equal output
        let t = bench.run(|| syrk_tiled(&a, 64, None)).median;
        table.row(vec![
            "syrk_tiled (64-row bands)".into(),
            format!("{}x{s}", 2 * s),
            fdur(t),
            gflops((2 * s) as f64 * (s * s) as f64, t),
        ]);
    }
    for &s in sizes {
        let a = Mat::from_fn(s + 8, s, |_, _| rng.gauss());
        let mut g = syrk_t(&a);
        for i in 0..s {
            g[(i, i)] += 1.0;
        }
        let t = bench.run(|| Cholesky::factor(&g).unwrap()).median;
        table.row(vec![
            "cholesky".into(),
            format!("{s}x{s}"),
            fdur(t),
            gflops((s * s * s) as f64 / 3.0, t),
        ]);
        let t = bench.run(|| Lu::factor(&g).unwrap()).median;
        table.row(vec![
            "lu".into(),
            format!("{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64 / 3.0, t),
        ]);
    }
    // pool-parallel GEMM (the dual backend's K_c build path)
    let pool = ThreadPool::with_default_size(8);
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, s, |_, _| rng.gauss());
        let t = bench.run(|| matmul_pool(&a, &b, Some(&pool))).median;
        table.row(vec![
            format!("gemm (pool×{})", pool.size()),
            format!("{s}x{s}x{s}"),
            fdur(t),
            gflops(2.0 * (s * s * s) as f64, t),
        ]);
    }
    // CSC sparse random projection (bigdata §4.5 "too many features" path);
    // ~1/3 density, flops ≈ 2·nnz·N
    let (n, p, q) = if tiny { (32, 500, 64) } else { (64, 2000, 256) };
    let x = Mat::from_fn(n, p, |_, _| rng.gauss());
    let proj = SparseProjection::sample(p, q, &mut rng);
    let t = bench.run(|| proj.project(&x)).median;
    table.row(vec![
        "sparse-projection (CSC)".into(),
        format!("{n}x{p}→{q}"),
        fdur(t),
        gflops(2.0 * proj.density() * (p * q * n) as f64, t),
    ]);

    // ---- per-ISA dispatch arms (BENCH_gemm.json) ----
    // Scalar is always first in `Isa::supported()`, so each shape's scalar
    // median is recorded before the vector arms that normalise against it.
    let isas = Isa::supported();
    let mut isa_rows: Vec<Json> = Vec::new();
    for &s in sizes {
        let a = Mat::from_fn(s, s, |_, _| rng.gauss());
        let b = Mat::from_fn(s, s, |_, _| rng.gauss());
        let tall = Mat::from_fn(2 * s, s, |_, _| rng.gauss());
        let gemm_flops = 2.0 * (s * s * s) as f64;
        let syrk_flops = (2 * s) as f64 * (s * s) as f64;
        let mut scalar_secs: BTreeMap<&str, f64> = BTreeMap::new();
        for &isa in &isas {
            for (kernel, secs, flops, size) in [
                (
                    "gemm",
                    bench.run(|| matmul_isa(&a, &b, isa)).median,
                    gemm_flops,
                    format!("{s}x{s}x{s}"),
                ),
                (
                    "syrk",
                    bench.run(|| syrk_t_isa(&tall, isa)).median,
                    syrk_flops,
                    format!("{}x{s}", 2 * s),
                ),
            ] {
                let scalar = *scalar_secs.entry(kernel).or_insert(secs);
                let speedup = scalar / secs;
                table.row(vec![
                    format!("{kernel} [{isa}]"),
                    size.clone(),
                    fdur(secs),
                    gflops(flops, secs),
                ]);
                let mut row = BTreeMap::new();
                row.insert("kernel".to_string(), Json::Str(kernel.to_string()));
                row.insert("isa".to_string(), Json::Str(isa.to_string()));
                row.insert("size".to_string(), Json::Str(size));
                row.insert("seconds".to_string(), Json::Num(secs));
                row.insert("gflops".to_string(), Json::Num(flops / secs / 1e9));
                row.insert("speedup_vs_scalar".to_string(), Json::Num(speedup));
                isa_rows.push(Json::Obj(row));
            }
        }
    }
    println!("{}", table.render());

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("simd_kernels".to_string()));
    doc.insert(
        "isas".to_string(),
        Json::Arr(isas.iter().map(|i| Json::Str(i.to_string())).collect()),
    );
    doc.insert("rows".to_string(), Json::Arr(isa_rows));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_gemm.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
