//! Ablation: out-of-core spilled Gram + Cholesky vs the tiled and one-shot
//! in-RAM builds (ROADMAP's "true out-of-core spill") → `BENCH_spill.json`.
//!
//! Over an N/P/tile grid, measures the dual **streaming-hat** build four
//! ways — one-shot (`TilePolicy::Off`), tiled (`Rows`, in-place factor),
//! spilled with RAM panels (`Spill { dir: None }`, the blocked out-of-core
//! schedule without disk IO), and spilled to disk files (`Spill { dir }`)
//! — plus the primal `syrk_tiled` vs `syrk_t` arm. Each row carries the
//! **resident-bytes model** (the accounting documented in
//! `docs/BACKENDS.md` "Out-of-core spill"): beyond the `O(NP)` streamed
//! outputs every arm shares, the spilled build holds only `O(tile·(N+P))`
//! slabs — the `N×N` never exists in RAM. Bitwise equality of all arms
//! rides along so the JSON records correctness, not just speed.
//!
//! Env: `FASTCV_BENCH_SCALE=tiny` for a fast smoke run (CI);
//! `FASTCV_BENCH_OUT` for the output directory.
//! Run: `cargo bench --bench ablation_spill`

use fastcv::bench::Bench;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::bigdata::StreamingHat;
use fastcv::fastcv::hat::GramBackend;
use fastcv::fastcv::ComputeContext;
use fastcv::linalg::{syrk_t, syrk_tiled, TilePolicy};
use fastcv::util::json::Json;
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, Table};
use std::collections::BTreeMap;

/// Transient resident bytes of the one-shot dual streaming build, beyond
/// the `xa`/`t` outputs all arms share: `X_c` + its transpose copy + `K_c`
/// + the out-of-place factor + the solve's RHS clone.
fn resident_one_shot(n: usize, p: usize) -> usize {
    8 * (2 * n * n + 3 * n * p)
}

/// Tiled build: the in-place factor (`N²`) + the in-place-solved centered
/// RHS (`N·P`) + tile-bounded slabs.
fn resident_tiled(n: usize, p: usize, tile: usize) -> usize {
    8 * (n * n + n * p + tile * (3 * p + n))
}

/// Spilled build: **no resident square at all** — the centered RHS solved
/// in place (`N·P`) + per-worker assembly slabs (three `tile×P` operands +
/// a `tile×N` band) + the factor/solve panels (≤ two `tile×N` + one
/// `N×tile` column strip ≈ `tile·N` terms, dominated by the band model).
fn resident_spill(n: usize, p: usize, tile: usize) -> usize {
    8 * (n * p + tile * (3 * p + 2 * n))
}

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let lambda = 1.0;
    // Wide shapes: spilling targets the P ≫ N dual quadrant (and, via
    // syrk_tiled, the P-huge primal one).
    let shapes: &[(usize, usize)] = if tiny { &[(24, 96)] } else { &[(100, 800), (200, 1600)] };
    let spill_base = std::env::temp_dir()
        .join(format!("fastcv-ablation-spill-{}", std::process::id()));

    let mut rows = Vec::new();
    let mut table = Table::new(vec![
        "shape",
        "tile",
        "one-shot",
        "tiled",
        "spill (RAM)",
        "spill (disk)",
        "resident spill/one-shot",
        "bitwise",
    ])
    .with_title("Ablation: spilled vs tiled vs one-shot dual streaming builds".to_string());

    for &(n, p) in shapes {
        let mut rng = Rng::new((n * 41 + p) as u64);
        let ds = generate(&SyntheticSpec::binary(n, p), &mut rng);
        let tiles: Vec<usize> = if tiny { vec![4, n / 2] } else { vec![16, 64, n / 2] };
        let threads = if tiny { 2 } else { 4 };

        let t_off = bench
            .run(|| StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap())
            .median;
        let reference =
            StreamingHat::build_with(&ds.x, lambda, GramBackend::Dual, None).unwrap();

        for tile in tiles {
            let ctx_for = |policy: TilePolicy| {
                ComputeContext::with_threads(threads)
                    .with_backend(GramBackend::Dual)
                    .with_tile_policy(policy)
            };
            let ctx_tiled = ctx_for(TilePolicy::Rows(tile));
            let ctx_ram = ctx_for(TilePolicy::Spill { dir: None, tile });
            let ctx_disk = ctx_for(TilePolicy::Spill { dir: Some(spill_base.clone()), tile });

            let t_tiled =
                bench.run(|| StreamingHat::build_ctx(&ds.x, lambda, &ctx_tiled).unwrap()).median;
            let t_ram =
                bench.run(|| StreamingHat::build_ctx(&ds.x, lambda, &ctx_ram).unwrap()).median;
            let t_disk =
                bench.run(|| StreamingHat::build_ctx(&ds.x, lambda, &ctx_disk).unwrap()).median;

            // correctness rides along: every arm bitwise-equal to one-shot
            let h_tiled = StreamingHat::build_ctx(&ds.x, lambda, &ctx_tiled).unwrap();
            let h_ram = StreamingHat::build_ctx(&ds.x, lambda, &ctx_ram).unwrap();
            let h_disk = StreamingHat::build_ctx(&ds.x, lambda, &ctx_disk).unwrap();
            let bitwise = reference.t.as_slice() == h_tiled.t.as_slice()
                && reference.t.as_slice() == h_ram.t.as_slice()
                && reference.t.as_slice() == h_disk.t.as_slice();

            let res_off = resident_one_shot(n, p);
            let res_spill = resident_spill(n, p, tile);
            let ratio = res_spill as f64 / res_off as f64;
            table.row(vec![
                format!("N={n} P={p}"),
                format!("{tile}"),
                fdur(t_off),
                fdur(t_tiled),
                fdur(t_ram),
                fdur(t_disk),
                format!("{ratio:.3}"),
                format!("{bitwise}"),
            ]);
            let mut row = BTreeMap::new();
            row.insert("n".to_string(), Json::Num(n as f64));
            row.insert("p".to_string(), Json::Num(p as f64));
            row.insert("tile".to_string(), Json::Num(tile as f64));
            row.insert("seconds_one_shot".to_string(), Json::Num(t_off));
            row.insert("seconds_tiled".to_string(), Json::Num(t_tiled));
            row.insert("seconds_spill_ram".to_string(), Json::Num(t_ram));
            row.insert("seconds_spill_disk".to_string(), Json::Num(t_disk));
            row.insert("resident_bytes_one_shot".to_string(), Json::Num(res_off as f64));
            row.insert(
                "resident_bytes_tiled".to_string(),
                Json::Num(resident_tiled(n, p, tile) as f64),
            );
            row.insert("resident_bytes_spill".to_string(), Json::Num(res_spill as f64));
            row.insert("resident_ratio_spill".to_string(), Json::Num(ratio));
            row.insert("bitwise_identical".to_string(), Json::Bool(bitwise));
            rows.push(Json::Obj(row));
        }
    }

    // Primal quadrant: the tiled syrk vs the one-shot kernel (the
    // ROADMAP "tiled primal syrk" rung — output bands instead of one
    // monolithic accumulation; bitwise-equal, so a pure memory knob).
    let mut syrk_rows = Vec::new();
    let (sn, sp) = if tiny { (48, 128) } else { (200, 1200) };
    let mut rng = Rng::new(7);
    let a = fastcv::linalg::Mat::from_fn(sn, sp, |_, _| rng.gauss());
    let t_syrk = bench.run(|| syrk_t(&a)).median;
    let g_ref = syrk_t(&a);
    for tile in if tiny { vec![8usize, 32] } else { vec![64usize, 256] } {
        let t_tiled = bench.run(|| syrk_tiled(&a, tile, None)).median;
        let bitwise = syrk_tiled(&a, tile, None).as_slice() == g_ref.as_slice();
        let mut row = BTreeMap::new();
        row.insert("n".to_string(), Json::Num(sn as f64));
        row.insert("p".to_string(), Json::Num(sp as f64));
        row.insert("tile".to_string(), Json::Num(tile as f64));
        row.insert("seconds_syrk_t".to_string(), Json::Num(t_syrk));
        row.insert("seconds_syrk_tiled".to_string(), Json::Num(t_tiled));
        row.insert("bitwise_identical".to_string(), Json::Bool(bitwise));
        syrk_rows.push(Json::Obj(row));
        table.row(vec![
            format!("syrk N={sn} P={sp}"),
            format!("{tile}"),
            fdur(t_syrk),
            fdur(t_tiled),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{bitwise}"),
        ]);
    }

    println!("{}", table.render());
    println!(
        "resident-bytes model: one-shot = 8·(2N² + 3NP), tiled = 8·(N² + NP + tile·(3P + N)), \
         spilled = 8·(NP + tile·(3P + 2N)) — no resident N×N; see docs/BACKENDS.md \
         \"Out-of-core spill\""
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("spilled_gram_builds".to_string()));
    doc.insert("lambda".to_string(), Json::Num(lambda));
    doc.insert("grid".to_string(), Json::Arr(rows));
    doc.insert("primal_syrk".to_string(), Json::Arr(syrk_rows));
    let out_dir = std::env::var("FASTCV_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{out_dir}/BENCH_spill.json");
    match std::fs::write(&path, Json::Obj(doc).dump()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&spill_base);
}
