//! §4.4 / §1 comparison: regularised-LDA analytic CV vs linear SVM
//! (dual coordinate descent) — accuracy parity, training-time contrast,
//! and the SVM's extra hyperparameter cost.
//!
//! Run: `cargo bench --bench svm_vs_lda`

use fastcv::bench::Bench;
use fastcv::cv::folds::stratified_kfold;
use fastcv::cv::metrics::accuracy_signed;
use fastcv::data::synthetic::{generate, SyntheticSpec};
use fastcv::fastcv::binary::AnalyticBinaryCv;
use fastcv::model::svm::{LinearSvm, SvmParams};
use fastcv::util::rng::Rng;
use fastcv::util::table::{fdur, fnum, Table};

fn main() {
    let tiny = std::env::var("FASTCV_BENCH_SCALE").as_deref() == Ok("tiny");
    let bench = if tiny {
        Bench { min_iters: 1, max_iters: 2, target_time: 0.05, warmup: 0 }
    } else {
        Bench::quick()
    };
    let configs: &[(usize, usize)] =
        if tiny { &[(60, 30)] } else { &[(100, 50), (100, 400), (300, 100)] };
    let mut table = Table::new(vec![
        "config",
        "LDA acc (analytic CV)",
        "SVM acc (CV)",
        "t LDA-CV",
        "t SVM-CV",
        "SVM/LDA time",
    ])
    .with_title("§4.4 — regularised LDA (analytic CV) vs linear SVM (DCD)".to_string());

    for &(n, p) in configs {
        let mut rng = Rng::new((n + p) as u64);
        let mut spec = SyntheticSpec::binary(n, p);
        spec.separation = 1.5;
        let ds = generate(&spec, &mut rng);
        let y = ds.y_signed();
        let folds = stratified_kfold(&ds.labels, 5, &mut rng);

        // LDA analytic CV
        let t_lda = bench
            .run(|| {
                let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
                cv.decision_values(&folds).unwrap()
            })
            .median;
        let cv = AnalyticBinaryCv::fit(&ds.x, &y, 1.0).unwrap();
        let acc_lda = accuracy_signed(&cv.decision_values(&folds).unwrap(), &y);

        // SVM CV (retrain per fold — no analytic shortcut exists for hinge loss)
        let svm_cv = |rng: &mut Rng| -> Vec<f64> {
            let mut dv = vec![0.0; n];
            for te in &folds {
                let tr = fastcv::fastcv::complement(te, n);
                let x_tr = ds.x.take_rows(&tr);
                let l_tr: Vec<usize> = tr.iter().map(|&i| ds.labels[i]).collect();
                let m = LinearSvm::train(&x_tr, &l_tr, SvmParams::default(), rng);
                for &i in te {
                    dv[i] = m.decision_value(ds.x.row(i));
                }
            }
            dv
        };
        let mut rng_b = Rng::new(7);
        let t_svm = bench.run(|| svm_cv(&mut rng_b)).median;
        let mut rng_c = Rng::new(7);
        let acc_svm = accuracy_signed(&svm_cv(&mut rng_c), &y);

        table.row(vec![
            format!("N={n} P={p}"),
            fnum(acc_lda, 3),
            fnum(acc_svm, 3),
            fdur(t_lda),
            fdur(t_svm),
            format!("{:.1}x", t_svm / t_lda),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper §1: LDA \"often performs similarly to linear SVM while being \
         significantly faster to train\" — and the SVM has no analytic CV shortcut."
    );
}
